"""Sharding rules: every (arch × mesh) param/cache spec must respect
divisibility (axes only assigned when the dim divides), and key tensors must
actually be distributed."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: AbstractMesh takes ((name, size), ...)
    AxisType = None

from repro.configs.base import SHAPES
from repro.configs.registry import ASSIGNED, get_config
from repro.models import sharding as SH
from repro.models.registry import build_model


def _mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return AbstractMesh(tuple(zip(axes, shape)))
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
                        for a in axes]))


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
@pytest.mark.parametrize("multi_pod", [False, True])
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_divisible(arch, multi_pod, mode):
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _mesh(multi_pod)
    specs = SH.param_pspecs(cfg, shapes, mesh, mode=mode)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for leaf, spec in zip(flat_shapes, flat_specs):
        assert len(spec) <= len(leaf.shape)
        for dim, axes in zip(leaf.shape, spec):
            size = _axis_size(mesh, axes)
            assert dim % size == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["nemotron-4-340b", "command-r-35b",
                                  "mixtral-8x7b", "deepseek-v2-lite-16b"])
def test_big_weights_are_sharded_in_train(arch):
    """No >1 GB parameter may stay fully replicated under the train rules."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _mesh(False)
    specs = SH.param_pspecs(cfg, shapes, mesh, mode="train")
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if nbytes > 1e9:
            shards = int(np.prod([_axis_size(mesh, a) for a in spec]))
            assert shards >= 16, (arch, leaf.shape, spec)


def test_expert_parallel_when_divisible():
    """deepseek (E=64) shards experts over model; mixtral (E=8) falls back to
    tensor-parallel d_ff."""
    mesh = _mesh(False)
    ds = get_config("deepseek-v2-lite-16b")
    m = build_model(ds)
    shapes = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    specs = SH.param_pspecs(ds, shapes, mesh, mode="train")
    assert specs["layers"]["moe"]["wg"][1] == "model"     # [L, E, d, f] EP
    mx = get_config("mixtral-8x7b")
    m = build_model(mx)
    shapes = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    specs = SH.param_pspecs(mx, shapes, mesh, mode="train")
    assert specs["layers"]["moe"]["wg"][1] != "model"
    assert specs["layers"]["moe"]["wg"][3] == "model"     # TP over f


def test_mqa_kv_not_sharded_seq_cache_instead():
    """granite (kv=1): kv heads can't shard over model=16 — the cache rules
    shard the sequence dim instead (distributed flash-decode)."""
    cfg = get_config("granite-20b")
    model = build_model(cfg)
    mesh = _mesh(False)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = SH.cache_pspecs(cfg, cache, mesh)
    k_spec = specs["layers"]["k"]            # [L, B, S, Hkv, hd]
    assert k_spec[2] == "model"              # seq sharded
    assert k_spec[3] is None


def test_serve_mode_weight_gather_for_big_models():
    """340B can't replicate per data shard: serve rules keep FSDP sharding."""
    cfg = get_config("nemotron-4-340b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _mesh(False)
    specs = SH.param_pspecs(cfg, shapes, mesh, mode="serve")
    wq = specs["layers"]["attn"]["wq"]       # [L, d, H, hd]
    assert wq[1] is not None                 # fsdp axis on
    small = get_config("llama3.2-3b")
    m2 = build_model(small)
    shapes2 = jax.eval_shape(lambda: m2.init(jax.random.PRNGKey(0)))
    specs2 = SH.param_pspecs(small, shapes2, mesh, mode="serve")
    assert specs2["layers"]["attn"]["wq"][1] is None     # replicated over data
