"""Substrate: optimizer, gradient compression, data pipeline, checkpointing,
fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataConfig, SyntheticLM, make_pipeline
from repro.checkpoint.checkpointer import Checkpointer
from repro.optim.grad_compress import (compress_with_error_feedback,
                                       init_error_feedback)
from repro.optim.optimizer import (AdamW, AdamW8bit, dequantize_i8,
                                   make_optimizer, quantize_i8, warmup_cosine)
from repro.runtime.fault_tolerance import (FailureInjector, StragglerDetector,
                                           plan_mesh, run_supervised)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _optimize(opt, steps=60):
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([0.5])}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2))(params)
        params, state, m = opt.update(grads, state, params)
    return params


def test_adamw_converges_quadratic():
    opt = AdamW(warmup_cosine(0.1, 2, 100), weight_decay=0.0)
    params = _optimize(opt)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw8bit_tracks_fp32():
    p32 = _optimize(AdamW(warmup_cosine(0.05, 2, 100), weight_decay=0.0))
    p8 = _optimize(AdamW8bit(warmup_cosine(0.05, 2, 100), weight_decay=0.0))
    for k in p32:
        np.testing.assert_allclose(np.asarray(p8[k]), np.asarray(p32[k]),
                                   atol=0.15)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 1000))
def test_quantize_roundtrip_bounded(seed, n):
    x = np.random.default_rng(seed).normal(size=n).astype(np.float32) * 10
    q, s = quantize_i8(jnp.asarray(x))
    back = np.asarray(dequantize_i8(q, s, (n,)))
    blockmax = np.abs(x).max() if n else 1.0
    # error bounded by half a quantization step of the worst block
    assert np.abs(back - x).max() <= (np.abs(x).max() / 127.0) * 0.5 + 1e-6


def test_grad_compression_error_feedback_unbiased():
    """With EF, the *accumulated* applied gradient converges to the true sum
    (residual stays bounded)."""
    g = {"w": jnp.full((300,), 0.003)}       # tiny gradient that int8 rounds
    ef = init_error_feedback(g)
    applied = jnp.zeros((300,))
    for i in range(50):
        cg, ef = compress_with_error_feedback(g, ef)
        applied = applied + cg["w"]
    true = 50 * 0.003
    np.testing.assert_allclose(np.asarray(applied), true, rtol=0.05)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_stable():
    ds = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=4))
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_data_host_sharding_disjoint():
    full = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                                  num_hosts=1, host_id=0)).batch_at(3)
    h0 = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                                num_hosts=2, host_id=0)).batch_at(3)
    h1 = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                                num_hosts=2, host_id=1)).batch_at(3)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetch_iterator_order():
    pipe = make_pipeline(type("C", (), {"vocab_size": 50})(),
                         type("S", (), {"seq_len": 8, "global_batch": 2})(),
                         start_step=5)
    ds = SyntheticLM(DataConfig(vocab_size=50, seq_len=8, global_batch=2))
    first = next(pipe)
    pipe.close()
    np.testing.assert_array_equal(first["tokens"], ds.batch_at(5)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
                 "opt": {"m": jnp.ones((4,))}}
        for s in (1, 2, 3):
            ck.save(s, state, blocking=True)
        assert ck.steps() == [2, 3]            # gc kept last 2
        like = jax.tree.map(jnp.zeros_like, state)
        out = ck.restore(3, like)
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(state["params"]["w"]))


def test_checkpoint_atomic_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, {"params": {"w": jnp.ones((2,))}}, blocking=True)
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_plan_mesh_preserves_model_axis():
    p = plan_mesh(512, 16)
    assert p["model"] == 16 and p["data"] == 32
    p = plan_mesh(500, 16)                    # lost 12 devices
    assert p["model"] == 16 and p["data"] == 16   # largest pow2 <= 31
    with pytest.raises(AssertionError):
        plan_mesh(8, 16)


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(threshold=2.0, patience=2)
    flagged = False
    for i in range(20):
        det.observe(0, 1.0 + 0.01 * np.random.default_rng(i).normal())
    for _ in range(3):
        flagged = det.observe(1, 5.0)
    assert flagged


def test_supervisor_restarts_and_finishes():
    """Simulated failures at steps 3 and 7; supervisor restarts from the
    last checkpoint and re-plans the mesh after device loss."""
    log = []
    fail_at = {3: True, 7: True}

    def train_loop(start, plan, devices):
        log.append((start, dict(plan), devices))
        for step in range(start, 10):
            if fail_at.pop(step, None):
                return step, False           # crash; checkpointed at `step`
        return 10, True

    inj = FailureInjector({3: 496, 7: 480})
    rep = run_supervised(train_loop, 10, 512, 16, injector=inj)
    assert rep.completed_steps == 10
    assert rep.restarts == 2
    assert rep.final_devices == 480
    assert log[0][2] == 512 and log[-1][2] == 480
    # mesh re-planned to fewer data shards after loss
    assert log[-1][1]["data"] <= log[0][1]["data"]


def test_run_supervised_reports_straggler_flags():
    """Regression (ISSUE 7 satellite): run_supervised always returned
    straggler_flags=[] — per-host step-time observations a train_loop
    reports (3-tuple return) now thread through the StragglerDetector, and
    the persistently slow host lands in the report.  The legacy 2-tuple
    return keeps working."""

    def train_loop(start, plan, devices):
        # host 0 is healthy (warmup + baseline); host 1 is persistently slow
        obs = [(0, 0.1)] * 8 + [(1, 10.0)] * 3
        return 10, True, obs

    rep = run_supervised(train_loop, 10, 8, 2)
    assert rep.straggler_flags == [1]
    assert rep.completed_steps == 10

    rep2 = run_supervised(lambda s, p, d: (10, True), 10, 8, 2)
    assert rep2.straggler_flags == []


def test_heartbeat():
    from repro.runtime.fault_tolerance import Heartbeat
    hb = Heartbeat(0, timeout_s=0.05)
    assert hb.alive()
    import time
    time.sleep(0.08)
    assert not hb.alive()
    hb.beat()
    assert hb.alive()
