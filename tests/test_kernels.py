"""Per-kernel allclose validation vs the pure-jnp oracles (interpret mode),
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.cache_moe import cache_moe, compact_occupied_slots
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.ssd_scan import ssd_scan

# interpret-mode Pallas sweeps dominate full-suite wall time; the fast tier
# (pytest -m "not slow") skips them — see pytest.ini
pytestmark = pytest.mark.slow

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.dtype(dtype).type] if False else \
        (2e-2 if jnp.dtype(dtype) == jnp.bfloat16 else 2e-5)


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D,bq,bk", [
    (1, 16, 16, 4, 4, 16, 8, 8),      # MHA square
    (2, 16, 32, 4, 2, 16, 8, 8),      # GQA, kv longer (decode-block case)
    (1, 32, 32, 8, 1, 32, 16, 16),    # MQA
    (1, 8, 8, 2, 2, 64, 8, 8),        # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Skv, H, Hkv, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("window", [4, 7, 16])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 16, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 16, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 16, 2, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=8, block_k=8, interpret=True)
    ref = R.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-3)


@pytest.mark.parametrize("B,S,H,Hkv,D,bk", [
    (2, 32, 4, 2, 16, 8),
    (1, 64, 8, 8, 32, 16),
    (3, 16, 2, 1, 64, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, H, Hkv, D, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, k, v, lengths, block_k=bk, interpret=True)
    ref = R.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("E,C,d,f,bc,bf,bd", [
    (4, 16, 32, 64, 8, 32, 16),
    (2, 8, 16, 32, 8, 16, 16),
    (8, 32, 64, 32, 16, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_sweep(E, C, d, f, bc, bf, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xg = jax.random.normal(ks[0], (E, C, d), dtype)
    wg = (jax.random.normal(ks[1], (E, d, f), dtype) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f), dtype) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, f, d), dtype) * 0.1).astype(dtype)
    valid = jax.random.bernoulli(ks[4], 0.7, (E, C))
    out = moe_gemm(xg, wg, wu, wd, valid, block_c=bc, block_f=bf, block_d=bd,
                   interpret=True)
    ref = R.moe_gemm_ref(xg, wg, wu, wd, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=2e-2)


# ---------------------------------------------------------------------------
# occupancy-compacted cache_moe: the slot grid covers min(S, T·k) occupied
# slots, not the whole pool — swept against the ragged cache_moe_ref oracle
# ---------------------------------------------------------------------------

def _cache_moe_inputs(T, k, S, d, f, seed, slot_ids):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (S, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (S, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (S, f, d)) * 0.1
    weights = jax.random.uniform(ks[4], (T, k))
    return x, wg, wu, wd, jnp.asarray(slot_ids, jnp.int32), weights


@pytest.mark.parametrize("case", ["empty_pool", "one_slot", "fully_occupied",
                                  "random_miss"])
def test_cache_moe_occupancy_compaction(case):
    """Large pool (S ≫ T·k, compaction active): empty occupancy (all
    slot_ids < 0), one occupied slot, a fully occupied small pool (S ≤ T·k,
    compaction a no-op), and random routing with misses all match the
    ragged oracle."""
    T, k, d, f = 4, 2, 32, 64
    if case == "empty_pool":
        S, slot_ids = 64, np.full((T, k), -1, np.int64)
    elif case == "one_slot":
        S, slot_ids = 64, np.full((T, k), 37, np.int64)
    elif case == "fully_occupied":
        S = 4                              # S ≤ T·k: no compaction branch
        slot_ids = np.arange(T * k).reshape(T, k) % S
    else:
        S = 64
        slot_ids = np.random.default_rng(0).integers(-1, S, size=(T, k))
    x, wg, wu, wd, si, w = _cache_moe_inputs(T, k, S, d, f, 11, slot_ids)
    out = cache_moe(x, si, w, wu, wd, wg, interpret=True)
    ref = R.cache_moe_ref(x, si, w, wu, wd, wg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-2)
    if case == "empty_pool":
        assert bool(jnp.all(out == 0))


def test_compact_occupied_slots_mapping():
    """The compaction helper renumbers densely, keeps misses at -1, and
    gathers exactly the occupied slots' weight rows."""
    S, M = 32, 6
    slot_ids = jnp.asarray([[30, -1], [7, 30], [19, 7]], jnp.int32)  # T·k=6
    wu = jnp.arange(S, dtype=jnp.float32)[:, None, None] * jnp.ones((S, 2, 3))
    comp, wu_c, wd_c, wg_c = compact_occupied_slots(slot_ids, wu, wu, None, M)
    comp = np.asarray(comp)
    assert wg_c is None and wu_c.shape == (M, 2, 3)
    # occupied slots {7, 19, 30} -> dense ranks {0, 1, 2} in slot order
    want = np.asarray([[2, -1], [0, 2], [1, 0]])
    np.testing.assert_array_equal(comp, want)
    np.testing.assert_array_equal(np.asarray(wu_c[:3, 0, 0]), [7., 19., 30.])


def test_cache_moe_compaction_matches_uncompacted():
    """Same routing computed against the full pool and against a pool just
    large enough to skip compaction must agree (the compacted grid is
    numerically transparent)."""
    T, k, d, f = 4, 2, 32, 32
    rng = np.random.default_rng(3)
    small_S = T * k                        # S ≤ T·k: no compaction
    slot_ids = rng.integers(-1, small_S, size=(T, k))
    x, wg, wu, wd, si, w = _cache_moe_inputs(T, k, small_S, d, f, 5, slot_ids)
    big_S = 48                             # same slots embedded in a big pool
    wg_b = jnp.concatenate([wg, jnp.zeros((big_S - small_S, d, f))])
    wu_b = jnp.concatenate([wu, jnp.zeros((big_S - small_S, d, f))])
    wd_b = jnp.concatenate([wd, jnp.zeros((big_S - small_S, f, d))])
    small = cache_moe(x, si, w, wu, wd, wg, interpret=True)
    big = cache_moe(x, si, w, wu_b, wd_b, wg_b, interpret=True)
    np.testing.assert_allclose(np.asarray(small), np.asarray(big),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 16, 2, 8, 4, 8),
    (2, 32, 3, 8, 4, 8),
    (1, 64, 1, 16, 8, 16),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    out = ssd_scan(x, dt, A, B, C, chunk, interpret=True)
    ref, _ = R.ssd_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)


def test_ssd_ref_matches_sequential():
    """The chunked SSD oracle itself vs a naive sequential recurrence."""
    b, s, h, p, n, chunk = 1, 12, 2, 4, 3, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_chunk, final = R.ssd_ref(x, dt, A, B, C, chunk)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, state = R.ssd_decode_ref(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               atol=1e-4, rtol=1e-3)


def test_ops_wrappers_dispatch():
    """kernels/ops.py: jit wrappers run (ref path on CPU) and match oracles."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 16, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 16, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 16, 2, 16), jnp.float32)
    auto = ops.attention(q, k, v)
    ref = R.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(ref), atol=1e-5)
    interp = ops.attention(q, k, v, impl="interpret")
    np.testing.assert_allclose(np.asarray(interp), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)
    x = jax.random.normal(ks[0], (1, 32, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.3)
    B = jax.random.normal(ks[0], (1, 32, 4))
    C = jax.random.normal(ks[1], (1, 32, 4))
    y1 = ops.ssd(x, dt, A, B, C, 8)
    y2 = ops.ssd(x, dt, A, B, C, 8, impl="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)
