"""Unified request-level serving API (core/engine.py): decode x offload
losslessness sweep through the single entry point, streaming vs one-shot
equivalence, cross-request warm-cache reuse, stop tokens (honoured
identically on every combination), per-request vs cumulative Metrics,
init-time precompilation of the fast verify path (no retrace on the fast
blocks), and Prefetcher.reset_stats ownership."""
import jax
import numpy as np
import pytest

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.engine import (DECODE_POLICIES, OFFLOAD_POLICIES, Engine,
                               EngineConfig, Request, derive_draft_config)
from repro.core.sd import greedy_generate
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def moe_setup():
    """Shared reduced-mixtral target/draft params + greedy reference."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = build_model(dcfg).init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                                cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 12, 64).tolist()
    return cfg, dcfg, tparams, dparams, prompt, ref


def _engine(ms, decode="sd", offload="spmoe", slots=8, **over):
    cfg, dcfg, tparams, dparams, _, _ = ms
    over.setdefault("draft_len", 3)
    over.setdefault("max_seq", 64)
    config = EngineConfig(model=cfg, draft=dcfg, decode=decode,
                          offload=offload, cache_slots=slots, **over)
    return Engine(config, tparams, dparams)


def _ample(ms):
    cfg = ms[0]
    return cfg.num_moe_layers * cfg.num_experts


# ---------------------------------------------------------------------------
# losslessness: every decode x offload combination, one entry point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offload", OFFLOAD_POLICIES)
@pytest.mark.parametrize("decode", DECODE_POLICIES)
def test_lossless_all_decode_offload_combinations(moe_setup, decode, offload):
    """The acceptance contract of the redesign: all 15 combinations emit the
    token stream of target-only greedy decoding, bit-identical."""
    _, _, _, _, prompt, ref = moe_setup
    with _engine(moe_setup, decode=decode, offload=offload,
                 max_draft_len=5) as eng:
        res = eng.submit(Request(prompt=prompt, max_new_tokens=12))
    assert res.tokens == ref, (decode, offload)
    assert res.finish_reason == "length"
    assert res.metrics.tokens == 12


# ---------------------------------------------------------------------------
# streaming sessions
# ---------------------------------------------------------------------------

def test_streaming_matches_one_shot(moe_setup):
    """stream() yields exactly the tokens submit() returns (and both match
    the greedy reference), on the same warm engine."""
    _, _, _, _, prompt, ref = moe_setup
    with _engine(moe_setup, slots=_ample(moe_setup)) as eng:
        streamed = list(eng.stream(Request(prompt=prompt, max_new_tokens=12)))
        assert eng.last_result.tokens == streamed
        res = eng.submit(Request(prompt=prompt, max_new_tokens=12))
    assert streamed == ref
    assert res.tokens == streamed


def test_cross_request_warm_cache_reuse(moe_setup):
    """A long-lived engine serves request 2 against the expert cache request
    1 warmed: the per-request hit rate must strictly improve."""
    cfg, _, tparams, _, prompt, ref = moe_setup
    prompt2 = jax.random.randint(jax.random.PRNGKey(7), (1, 6), 0,
                                 cfg.vocab_size)
    with _engine(moe_setup, slots=_ample(moe_setup)) as eng:
        r1 = eng.submit(Request(prompt=prompt, max_new_tokens=12))
        r2 = eng.submit(Request(prompt=prompt2, max_new_tokens=12))
        cum = eng.metrics()
    assert r1.tokens == ref
    assert r2.metrics.hit_rate > r1.metrics.hit_rate
    assert r2.metrics.on_demand_loads == 0       # fully cache-resident
    # cumulative view = sum of the per-request snapshots
    assert cum.requests == 2
    assert cum.tokens == r1.metrics.tokens + r2.metrics.tokens
    assert cum.hits == r1.metrics.hits + r2.metrics.hits


# ---------------------------------------------------------------------------
# stop tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decode,offload", [
    ("greedy", "none"), ("sd", "none"), ("sd-adaptive", "none"),
    ("sd", "spmoe"), ("greedy", "on-demand"), ("sd-adaptive", "moe-infinity"),
])
def test_stop_tokens_identical_across_combinations(moe_setup, decode, offload):
    """A stop token ends the request right after it is committed — at the
    same position on every decode x offload combination (the committed
    stream is identical, so truncation is too)."""
    _, _, _, _, prompt, ref = moe_setup
    stop = ref[4]
    with _engine(moe_setup, decode=decode, offload=offload,
                 max_draft_len=5) as eng:
        res = eng.submit(Request(prompt=prompt, max_new_tokens=12,
                                 stop_tokens=(stop,)))
    assert res.tokens == ref[:5], (decode, offload)
    assert res.finish_reason == "stop"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_same_keys_on_every_path(moe_setup):
    """The Metrics surface is path-independent: identical keys whether the
    request ran without offload or through the full SP-MoE pipeline."""
    _, _, _, _, prompt, _ = moe_setup
    with _engine(moe_setup, offload="none") as e1:
        m1 = e1.submit(Request(prompt=prompt, max_new_tokens=8)).metrics
    with _engine(moe_setup, offload="spmoe") as e2:
        m2 = e2.submit(Request(prompt=prompt, max_new_tokens=8)).metrics
    assert set(m1.as_dict()) == set(m2.as_dict())
    # no-offload path reports zeros on the offload plane, not missing keys
    assert m1.lookups == 0 and m1.prefetched == 0 and m1.host_syncs == 0
    assert m2.lookups > 0
    # decode-plane counters live on both
    assert m1.iterations > 0 and m2.iterations > 0
    assert m1.drafted == m1.iterations * 3


def test_engine_reset_stats_and_prefetcher_ownership(moe_setup):
    """Engine.reset_stats goes through Prefetcher.reset_stats — no caller
    pokes prefetcher internals — and zeroes the cumulative view."""
    _, _, _, _, prompt, _ = moe_setup
    with _engine(moe_setup) as eng:
        eng.submit(Request(prompt=prompt, max_new_tokens=8))
        pf = eng.runtime.prefetcher
        assert pf.loaded_count > 0 and pf.io_events
        eng.reset_stats()
        assert pf.loaded_count == 0 and pf.io_events == []
        assert eng.metrics().requests == 0 and eng.metrics().tokens == 0


def test_metrics_getitem_compat(moe_setup):
    _, _, _, _, prompt, _ = moe_setup
    with _engine(moe_setup) as eng:
        m = eng.submit(Request(prompt=prompt, max_new_tokens=6)).metrics
    assert m["hit_rate"] == m.hit_rate
    assert m["fast_blocks"] == m.fast_blocks
    assert m["cutoff_layer"] == eng.cutoff_layer


# ---------------------------------------------------------------------------
# precompiled fast verify path (ROADMAP open item)
# ---------------------------------------------------------------------------

def test_no_retrace_on_second_fast_block(moe_setup):
    """Engine init pre-traces _verify_fast for the decode block shape; the
    armed fast blocks of the first request reuse that executable — the trace
    count stays at the single init-time trace."""
    _, _, _, _, prompt, ref = moe_setup
    with _engine(moe_setup, slots=_ample(moe_setup)) as eng:
        rt = eng.runtime
        assert rt._fast_traces == 1, "init did not pre-trace the fast path"
        res = eng.submit(Request(prompt=prompt, max_new_tokens=12))
        assert res.metrics.fast_blocks >= 2, "fast path never engaged"
        assert rt._fast_traces == 1, \
            "fast verify path retraced after engine init"
    assert res.tokens == ref


# ---------------------------------------------------------------------------
# config validation / request normalization
# ---------------------------------------------------------------------------

def test_engine_config_validation(moe_setup):
    cfg = moe_setup[0]
    dense = derive_draft_config(cfg)          # dense sibling
    with pytest.raises(ValueError):
        EngineConfig(model=dense, offload="spmoe")      # offload needs MoE
    with pytest.raises(ValueError):
        EngineConfig(model=cfg, decode="beam")          # unknown policy
    with pytest.raises(ValueError):
        EngineConfig(model=cfg, decode="sd", draft_len=0)
    c = EngineConfig(model=cfg, decode="greedy", offload="on-demand")
    assert c.initial_draft_len == 0 and not c.needs_draft


def test_request_prompt_normalization(moe_setup):
    _, _, _, _, prompt, ref = moe_setup
    as_list = [int(t) for t in np.asarray(prompt)[0]]
    with _engine(moe_setup, decode="greedy", offload="none") as eng:
        res = eng.submit(Request(prompt=as_list, max_new_tokens=8))
    assert res.tokens == ref[:8]
