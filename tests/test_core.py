"""SP-MoE core: LRU cache invariants (property), cutoff solver, prefetcher
pipeline, offload engine losslessness + prefetch accounting."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.cache import ExpertCache
from repro.core.cutoff import HardwareProfile, solve_cutoff
from repro.core.engine import Engine, EngineConfig, Request
from repro.core.offload import HostExpertStore
from repro.core.prefetcher import Prefetcher
from repro.core.predictor import ExpertPredictor, strategy_entropies
from repro.core.sd import greedy_generate


# ---------------------------------------------------------------------------
# LRU expert cache
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["insert", "lookup"]),
                          st.integers(0, 5), st.integers(0, 7)),
                min_size=1, max_size=60),
       st.integers(1, 6))
def test_cache_invariants_under_op_sequences(ops, slots):
    """Any interleaving of inserts/lookups keeps the page table consistent:
    no slot aliasing, table==lru keys, free+used==capacity."""
    cache = ExpertCache(slots, {"w": (2, 2)}, jnp.float32)
    arrays = {"w": np.ones((1, 2, 2), np.float32)}
    for op, layer, expert in ops:
        key = (layer, expert)
        if op == "insert":
            cache.insert([key], arrays)
        else:
            cache.lookup([key])
        assert cache.check_invariants()
    assert len(cache.table) <= slots


def test_cache_lru_eviction_order():
    cache = ExpertCache(2, {"w": (1,)}, jnp.float32)
    a = {"w": np.zeros((1, 1), np.float32)}
    cache.insert([(0, 0)], a)
    cache.insert([(0, 1)], a)
    cache.lookup([(0, 0)])             # touch 0 -> 1 becomes LRU victim
    cache.insert([(0, 2)], a)
    assert cache.contains((0, 0))
    assert not cache.contains((0, 1))
    assert cache.contains((0, 2))
    assert cache.check_invariants()


def test_cache_batched_insert_contents():
    cache = ExpertCache(4, {"w": (2,)}, jnp.float32)
    arrays = {"w": np.stack([np.full((2,), i, np.float32) for i in range(3)])}
    slots = cache.insert([(0, 0), (0, 1), (0, 2)], arrays)
    bufs = np.asarray(cache.bufs["w"])
    for i, s in enumerate(slots):
        np.testing.assert_array_equal(bufs[s], np.full((2,), i))


# ---------------------------------------------------------------------------
# cutoff solver (paper §3.2)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.floats(1e-4, 1e-2), st.floats(1e-4, 1e-2), st.floats(1e-3, 3e-2),
       st.integers(4, 48), st.integers(1, 6), st.integers(1, 8),
       st.floats(1e9, 40e9))
def test_cutoff_satisfies_constraints(t_comp, t_draft, t_io, layers, k,
                                      draft_len, mem_gpu):
    prof = HardwareProfile(t_comp=t_comp, t_comp_draft=t_draft, t_io=t_io,
                           mem_gpu=mem_gpu, mem_peak=mem_gpu * 0.3,
                           mem_expert=300e6)
    dec = solve_cutoff(prof, k, layers, draft_len)
    L = dec.cutoff_layer
    assert -1 <= L < layers
    if L >= 0:
        n = (L + 1) * k
        # memory constraint
        assert prof.mem_peak + n * prof.mem_expert < prof.mem_gpu
        # overlap constraint (paper's inequality)
        budget = layers * t_draft * draft_len
        assert max((L - 1) * t_draft + k * t_io, n * t_io) <= budget + 1e-12
    if L + 1 < layers:
        # maximality: L+1 must violate one constraint
        n2 = (L + 2) * k
        budget = layers * t_draft * draft_len
        mem_bad = prof.mem_peak + n2 * prof.mem_expert >= prof.mem_gpu
        ovl_bad = max(L * t_draft + k * t_io, n2 * t_io) > budget
        assert mem_bad or ovl_bad


# ---------------------------------------------------------------------------
# prefetcher pipeline
# ---------------------------------------------------------------------------

def _toy_engine(policy="spmoe", slots=6):
    """Unified-API engine (core/engine.py); eng.runtime is the offload
    layer underneath."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    from repro.models.registry import build_model
    target = build_model(cfg)
    draft = build_model(dcfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = draft.init(jax.random.PRNGKey(1))
    eng = Engine(EngineConfig(model=cfg, draft=dcfg, decode="sd",
                              offload=policy, cache_slots=slots,
                              draft_len=3, max_seq=48), tparams, dparams)
    return cfg, target, tparams, eng


def test_prefetch_worker_loads_async():
    cfg, target, tparams, eng = _toy_engine()
    rt = eng.runtime
    keys = [(0, 0), (0, 1), (1, 2)]
    task = rt.prefetcher.submit(keys)
    task.done.wait(timeout=10)
    assert all(rt.cache.contains(k) for k in keys)
    assert rt.prefetcher.loaded_count == 3
    assert rt.prefetcher.io_events == [3]       # batched: one transfer
    eng.close()


def test_prefetcher_unbatched_issues_per_expert():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    from repro.models.registry import build_model
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams)
    cache = ExpertCache(8, store.buffer_shapes(), jnp.float32)
    pf = Prefetcher(store, cache, mode="worker", batched=False)
    task = pf.submit([(0, 0), (1, 1), (2, 2)])
    task.done.wait(timeout=10)
    assert pf.io_events == [1, 1, 1]
    pf.stop()


@pytest.mark.parametrize("policy", ["spmoe", "on-demand"])
def test_offload_engine_lossless(policy):
    cfg, target, tparams, eng = _toy_engine(policy)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 14, 48)
    res = eng.submit(Request(prompt=prompt, max_new_tokens=14))
    out, stats = res.token_array(), res.metrics
    eng.close()
    assert out.tolist() == ref.tolist()
    if policy == "spmoe":
        assert stats["prefetched"] > 0
    else:
        assert stats["prefetched"] == 0
        assert stats["on_demand_loads"] > 0


def test_spmoe_prefetch_improves_hit_rate():
    _, _, _, e1 = _toy_engine("on-demand", slots=10)
    cfg, _, _, e2 = _toy_engine("spmoe", slots=10)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab_size)
    s1 = e1.submit(Request(prompt=prompt, max_new_tokens=12)).metrics
    s2 = e2.submit(Request(prompt=prompt, max_new_tokens=12)).metrics
    e1.close()
    e2.close()
    assert s2["hit_rate"] >= s1["hit_rate"]


# ---------------------------------------------------------------------------
# predictor analytics (Observation I)
# ---------------------------------------------------------------------------

def test_strategy_entropies_ordering():
    """Gating-based prediction must be lower-entropy than random; Fig 2c."""
    rng = np.random.default_rng(0)
    E, T = 8, 64
    logits = rng.normal(size=(T, E)) * 3.0        # skewed per-token gates
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    hist = rng.integers(1, 100, size=E).astype(float)
    ent = strategy_entropies(probs, hist)
    assert ent["gating_based"] < ent["random"]
    assert ent["coarse_grained"] <= ent["random"] + 1e-6


def test_predictor_matches_gate_topk():
    cfg, target, tparams, eng = _toy_engine()
    pred = eng.runtime.predictor
    tap = jax.random.normal(jax.random.PRNGKey(3), (1, 1, cfg.d_model))
    keys = pred.predict_layer(0, tap)
    # manual: top-k of softmax(tap @ gate_0)
    gate = np.asarray(tparams["layers"]["moe"]["gate"])[0]
    scores = np.asarray(tap).reshape(-1) @ gate
    top = set(np.argsort(-scores)[: pred.k].tolist())
    assert {e for (_, e) in keys} == top
    eng.close()
