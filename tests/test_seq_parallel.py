"""Sequence-parallel Mamba2 (models/mamba_sp.py): numerical equivalence with
the reference forward under a real sharded mesh (subprocess with fabricated
devices — the main test process keeps the single CPU device)."""
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.parametrize("shards", [2, 4])
def test_seq_parallel_matches_reference(shards):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.registry import build_model
        from repro.models.mamba_sp import seq_parallel_forward
        from repro.launch.mesh import _make_mesh
        cfg = get_config("mamba2-780m").reduced(dtype="float32", ssm_chunk=8)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                    cfg.vocab_size)
        ref, _ = model.forward(params, tokens)
        mesh = _make_mesh((8 // {shards}, {shards}), ("data", "model"))
        with mesh:
            out = jax.jit(lambda p, t: seq_parallel_forward(p, t, cfg, mesh))(
                params, tokens)
        err = float(np.abs(np.asarray(out) - np.asarray(ref[:, -1])).max())
        assert err < 1e-3, err
        print("ERR", err)
    """)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # skip TPU-plugin probing (60s+ stall when a
                              # libtpu is installed but no TPU is attached)
                              "JAX_PLATFORMS": "cpu"})
    assert res.returncode == 0, res.stderr[-2000:]
    assert "ERR" in res.stdout
