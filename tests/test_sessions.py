"""Concurrent sessions on one warm cache (Engine.serve round-robin
scheduler) plus the serving-loop correctness fixes that rode along:
interleaved sessions bit-identical to their solo greedy references on every
decode x offload combination, per-request Metrics isolation under
interleaving, the ≤2-syncs-per-block contract with concurrency on,
abandoned streams reporting finish_reason="aborted" (engine stays
reusable), Prefetcher.submit after stop() no longer hanging drain(),
Metrics.add preserving the cutoff_layer echo, and the sd-adaptive
draft-length ladder pre-traced at engine init."""
import time

import jax
import jax.numpy as jnp
import pytest

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.cache import ExpertCache
from repro.core.engine import (DECODE_POLICIES, OFFLOAD_POLICIES, Engine,
                               EngineConfig, Metrics, Request)
from repro.core.offload import HostExpertStore
from repro.core.prefetcher import Prefetcher
from repro.core.sd import greedy_generate
from repro.models.registry import build_model

TOK = 10


@pytest.fixture(scope="module")
def ms():
    """Reduced-mixtral target/draft params, two prompts, their greedy refs."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = build_model(dcfg).init(jax.random.PRNGKey(1))
    prompts = [jax.random.randint(jax.random.PRNGKey(2 + i), (1, 6), 0,
                                  cfg.vocab_size) for i in range(2)]
    refs = [greedy_generate(target, tparams, p, TOK, 64).tolist()
            for p in prompts]
    return cfg, dcfg, tparams, dparams, prompts, refs


def _engine(ms, decode="sd", offload="spmoe", slots=None, **over):
    cfg, dcfg, tparams, dparams, _, _ = ms
    if slots is None:
        slots = cfg.num_moe_layers * cfg.num_experts    # ample
    over.setdefault("draft_len", 3)
    over.setdefault("max_seq", 64)
    return Engine(EngineConfig(model=cfg, draft=dcfg, decode=decode,
                               offload=offload, cache_slots=slots, **over),
                  tparams, dparams)


def _reqs(prompts, **kw):
    return [Request(prompt=p, max_new_tokens=TOK, **kw) for p in prompts]


# ---------------------------------------------------------------------------
# interleaving is lossless — every decode x offload combination
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offload", OFFLOAD_POLICIES)
@pytest.mark.parametrize("decode", DECODE_POLICIES)
def test_interleaved_sessions_lossless_all_combinations(ms, decode, offload):
    """The acceptance contract of the scheduler: two sessions round-robined
    on one warm cache each emit the token stream of serving them alone —
    which is the solo greedy reference — on all 15 combinations.  A tight
    cache keeps the offload combos under real miss/eviction pressure while
    the sessions compete for slots."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, decode=decode, offload=offload, slots=8,
                 max_draft_len=5) as eng:
        res = eng.serve_all(_reqs(prompts), concurrency=2)
    for r, ref in zip(res, refs):
        assert r.tokens == ref, (decode, offload)
        assert r.finish_reason == "length"
        assert r.metrics.tokens == TOK


def test_serve_yields_interleaved_commit_order(ms):
    """serve() is a real round-robin: session 1 commits tokens before
    session 0 finishes, streams reassemble losslessly from the event
    stream, and last_batch lands in submission order."""
    _, _, _, _, prompts, refs = ms
    reqs = [Request(prompt=p, max_new_tokens=TOK, request_id=f"s{i}")
            for i, p in enumerate(prompts)]
    with _engine(ms) as eng:
        events = list(eng.serve(reqs, concurrency=2))
        res = eng.last_batch
    streams = {"s0": [], "s1": []}
    for rid, tok in events:
        streams[rid].append(tok)
    assert streams["s0"] == refs[0] and streams["s1"] == refs[1]
    first_s1 = next(i for i, (rid, _) in enumerate(events) if rid == "s1")
    last_s0 = max(i for i, (rid, _) in enumerate(events) if rid == "s0")
    assert first_s1 < last_s0, "sessions were served serially, not interleaved"
    assert [r.request_id for r in res] == ["s0", "s1"]
    assert all(r.finish_reason == "length" for r in res)


def test_stop_token_and_admission_beyond_concurrency(ms):
    """A stop token retires one session mid-flight without disturbing its
    neighbours, and a third request is admitted once a slot frees up."""
    _, _, _, _, prompts, refs = ms
    stop = refs[0][4]
    reqs = [Request(prompt=prompts[0], max_new_tokens=TOK,
                    stop_tokens=(stop,)),
            Request(prompt=prompts[1], max_new_tokens=TOK),
            Request(prompt=prompts[0], max_new_tokens=TOK)]
    with _engine(ms) as eng:
        res = eng.serve_all(reqs, concurrency=2)
    assert res[0].tokens == refs[0][:5] and res[0].finish_reason == "stop"
    assert res[1].tokens == refs[1] and res[1].finish_reason == "length"
    assert res[2].tokens == refs[0] and res[2].finish_reason == "length"


# ---------------------------------------------------------------------------
# per-request metrics stay isolated when sessions interleave
# ---------------------------------------------------------------------------

def test_metrics_isolated_under_interleaving(ms):
    """Each interleaved session's Metrics delta equals its solo run on the
    deterministic (schedule-independent) counters, and the per-session
    ledgers tile the engine-cumulative delta exactly — nothing double-
    counted across sessions, nothing dropped."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as solo_eng:
        solo = [solo_eng.submit(r) for r in _reqs(prompts)]
    with _engine(ms) as eng:
        before = eng.metrics()
        res = eng.serve_all(_reqs(prompts), concurrency=2)
        after = eng.metrics()
    for r, s in zip(res, solo):
        assert r.tokens == s.tokens
        for k in ("tokens", "iterations", "drafted", "accepted",
                  "verify_blocks"):
            assert r.metrics[k] == s.metrics[k], k
    # ledger completeness over the synchronously-updated counters (the
    # async I/O counters — prefetched/evictions — can land between turns)
    for k in ("iterations", "drafted", "accepted", "verify_blocks",
              "fast_blocks", "fast_fallbacks", "host_syncs",
              "on_demand_loads", "lookups", "hits", "tokens", "requests"):
        assert sum(r.metrics[k] for r in res) == after[k] - before[k], k


# ---------------------------------------------------------------------------
# sync contract survives concurrency
# ---------------------------------------------------------------------------

def test_sync_contract_two_syncs_per_block_with_concurrency(ms):
    """With an ample cache and two interleaved sessions: solo fast blocks
    (the prefills) still perform exactly ONE host sync inside _verify_block,
    and every batched decode ROUND — which commits BOTH sessions' verify
    blocks in one fused dispatch — performs ≤2 host syncs total, i.e. the
    old 2-per-block contract became 2-per-round.  Both streams stay
    lossless."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as eng:
        rt = eng.runtime
        eng.serve_all(_reqs(prompts), concurrency=2)    # warm cache + arming
        per_block, per_round = [], []
        orig_vb = rt._verify_block
        orig_turns = rt.session_turns

        def spy_vb(tokens, pos, tcache):
            before_sync, before_fast = rt.host_syncs, rt.fast_blocks
            out = orig_vb(tokens, pos, tcache)
            per_block.append((rt.host_syncs - before_sync,
                              rt.fast_blocks > before_fast))
            return out

        def spy_turns(sts):
            before_sync, before_fast = rt.host_syncs, rt.fast_blocks
            out = orig_turns(sts)
            per_round.append((rt.host_syncs - before_sync,
                              rt.fast_blocks - before_fast))
            return out

        rt._verify_block = spy_vb
        rt.session_turns = spy_turns
        res = eng.serve_all(_reqs(prompts), concurrency=2)
        rt._verify_block = orig_vb
        rt.session_turns = orig_turns
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    fast = [s for s, is_fast in per_block if is_fast]
    assert fast, "solo fast path never engaged (prefill blocks)"
    assert max(fast) == 1, f"fast block synced more than once: {per_block}"
    fused = [(s, b) for s, b in per_round if b == 2]
    assert fused, "no round committed both sessions' blocks fused"
    assert max(s for s, _ in fused) <= 2, \
        f"a fused round exceeded 2 host syncs: {per_round}"
    assert all(r.metrics.fast_fallbacks == 0 for r in res)


# ---------------------------------------------------------------------------
# abandoned consumers: finish_reason="aborted", engine stays reusable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decode,offload", [("sd", "spmoe"),
                                            ("greedy", "none")])
def test_abandoned_stream_reports_aborted_and_engine_reusable(
        ms, decode, offload):
    """Regression: GeneratorExit used to hit stream()'s finally with finish
    still at its "length" default.  An abandoned stream must report
    "aborted" — and the engine must keep serving afterwards."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, decode=decode, offload=offload) as eng:
        g = eng.stream(Request(prompt=prompts[0], max_new_tokens=TOK))
        first = next(g)
        g.close()                       # consumer walks away mid-stream
        res = eng.last_result
        assert res.finish_reason == "aborted"
        assert res.tokens[0] == first and len(res.tokens) < TOK
        assert res.metrics.requests == 1
        res2 = eng.submit(Request(prompt=prompts[0], max_new_tokens=TOK))
        assert res2.tokens == refs[0]
        assert res2.finish_reason == "length"
        assert eng.metrics().requests == 2


def test_serve_close_aborts_active_sessions(ms):
    """Closing the serve() iterator retires every unfinished session as
    "aborted", publishes last_batch, and leaves the engine reusable; a
    never-started iterator leaves last_batch empty, never stale."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as eng:
        eng.submit(Request(prompt=prompts[0], max_new_tokens=2))
        never_started = eng.serve(_reqs(prompts), concurrency=2)
        never_started.close()
        assert eng.last_batch == []     # not the previous request's results
        it = eng.serve(_reqs(prompts), concurrency=2)
        next(it)
        next(it)                        # both sessions have committed tokens
        it.close()
        res = eng.last_batch
        assert len(res) == 2
        assert all(r is not None and r.finish_reason == "aborted"
                   for r in res)
        r = eng.submit(Request(prompt=prompts[0], max_new_tokens=TOK))
        assert r.tokens == refs[0]


# ---------------------------------------------------------------------------
# prefetcher: submit after stop() must not hang drain()
# ---------------------------------------------------------------------------

def test_prefetcher_submit_after_stop_executes_inline_and_drains(ms):
    """Regression: a task enqueued with no worker thread incremented
    _inflight with nothing left to decrement it, so the next drain() waited
    forever.  submit-after-stop now degrades to synchronous execution."""
    cfg, _, tparams, _, _, _ = ms
    store = HostExpertStore(cfg, tparams)
    cache = ExpertCache(8, store.buffer_shapes(), jnp.float32,
                        table_shape=(store.num_layers, store.num_experts))
    pf = Prefetcher(store, cache, mode="worker", batched=True)
    pf.stop()
    task = pf.submit([(0, 0), (1, 1)])
    assert task is not None and task.done.is_set()
    assert cache.contains((0, 0)) and cache.contains((1, 1))
    t0 = time.perf_counter()
    pf.drain()                          # used to hang forever
    assert time.perf_counter() - t0 < 2.0
    assert pf.loaded_count == 2


# ---------------------------------------------------------------------------
# per-session I/O is attributed to the task OWNER, not the turn it lands in
# ---------------------------------------------------------------------------

def test_prefetch_io_attributed_to_task_owner(ms):
    """Regression (ROADMAP open item): with an async worker, a prefetch load
    could land between two sessions' turns and be charged — via the
    turn-window counter delta — to the wrong session's ledger.  I/O now
    rides on the task: a slowed store guarantees the two sessions' prefetch
    waves interleave across turn boundaries, and each session's ledger must
    equal exactly the loads of the tasks IT submitted, with every eviction
    owned by exactly one session."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, slots=24) as eng:       # tight-ish: eviction pressure
        rt = eng.runtime
        orig_fetch = rt.store.fetch

        def slow_fetch(keys):
            time.sleep(0.02)                 # push completion past the turn
            return orig_fetch(keys)

        rt.store.fetch = slow_fetch
        owned = {}
        orig_prefetch = rt._prefetch

        def spy_prefetch(st, keys):
            n0 = len(st.inflight)
            orig_prefetch(st, keys)
            owned.setdefault(id(st), []).extend(st.inflight[n0:])

        rt._prefetch = spy_prefetch
        st1 = rt.start_session(prompts[0], TOK)
        st2 = rt.start_session(prompts[1], TOK)
        while not (st1.finished and st2.finished):   # interleave waves
            if not st1.finished:
                rt.session_turn(st1)
            if not st2.finished:
                rt.session_turn(st2)
        rt.finish_session(st1)
        rt.finish_session(st2)
        rt._prefetch = orig_prefetch
        rt.store.fetch = orig_fetch
        for st in (st1, st2):
            want = sum(t.stats.get("prefetched", 0)
                       for t in owned.get(id(st), []))
            assert st.io["prefetched"] == want
        assert st1.io["prefetched"] > 0 and st2.io["prefetched"] > 0
        # totals tile: every load and every eviction has exactly one owner
        assert st1.io["prefetched"] + st2.io["prefetched"] == \
            rt.prefetcher.loaded_count
        assert st1.io["evictions"] + st2.io["evictions"] == \
            rt.cache.evictions


# ---------------------------------------------------------------------------
# Metrics.add keeps the cutoff_layer configuration echo
# ---------------------------------------------------------------------------

def test_metrics_add_preserves_cutoff_echo():
    """Regression: adding a default-constructed Metrics (cutoff_layer=-1)
    used to wipe the configured echo back to -1."""
    m = Metrics(cutoff_layer=3)
    m.add(Metrics())
    assert m.cutoff_layer == 3
    m.add(Metrics(cutoff_layer=5))
    assert m.cutoff_layer == 5


# ---------------------------------------------------------------------------
# sd-adaptive x offload: the whole draft-length ladder is pre-traced
# ---------------------------------------------------------------------------

def test_adaptive_ladder_precompiled(ms):
    """ROADMAP open item closed: engine init pre-traces _verify_fast for
    every draft length in [min_draft_len, max_draft_len], so no adapted
    length retraces under the cache lock mid-serve."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, decode="sd-adaptive", min_draft_len=1,
                 max_draft_len=3) as eng:
        rt = eng.runtime
        assert rt._fast_traces == 3, "draft-length ladder not pre-traced"
        res = eng.submit(Request(prompt=prompts[0], max_new_tokens=TOK))
        assert res.metrics.fast_blocks >= 1, "fast path never engaged"
        assert rt._fast_traces == 3, \
            "adapted draft length retraced after engine init"
    assert res.tokens == refs[0]
