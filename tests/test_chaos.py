"""Chaos-hardened serving: the fault-injected I/O plane, the supervised
prefetch worker, and the graceful-degradation ladder.

The acceptance contract mirrors the paper's losslessness guarantee under a
hostile I/O plane: with seeded fault injection (transient fetch/insert
errors, latency spikes, staged-payload corruption, worker kills) every
decode x offload combination commits the BIT-IDENTICAL token stream of a
fault-free run — injected faults cost latency, never correctness.  The
units underneath: retry-with-backoff, per-task deadlines, checksum
quarantine-and-refetch, supervised worker restart, bounded drains and
error rings, the degradation ladder's on-demand rung, the per-request
``io_error`` rung (real faults only), and per-request deadlines."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.cache import ExpertCache
from repro.core.chaos import (ChaosConfig, ChaosError, ChaosInjector,
                              PayloadCorruption)
from repro.core.engine import (DECODE_POLICIES, OFFLOAD_POLICIES, Engine,
                               EngineConfig, Request)
from repro.core.offload import HostExpertStore
from repro.core.prefetcher import Prefetcher
from repro.core.sd import greedy_generate
from repro.models.registry import build_model

TOK = 10

CHAOS = ChaosConfig(seed=7, fetch_error_rate=0.2, insert_error_rate=0.05,
                    spike_rate=0.05, spike_s=0.001, corrupt_rate=0.1,
                    kill_worker_every=5)


@pytest.fixture(scope="module")
def ms():
    """Reduced-mixtral target/draft params, two prompts, their greedy refs."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = build_model(dcfg).init(jax.random.PRNGKey(1))
    prompts = [jax.random.randint(jax.random.PRNGKey(2 + i), (1, 6), 0,
                                  cfg.vocab_size) for i in range(2)]
    refs = [greedy_generate(target, tparams, p, TOK, 64).tolist()
            for p in prompts]
    return cfg, dcfg, tparams, dparams, prompts, refs


def _engine(ms, decode="sd", offload="spmoe", slots=None, **over):
    cfg, dcfg, tparams, dparams, _, _ = ms
    if slots is None:
        slots = cfg.num_moe_layers * cfg.num_experts
    over.setdefault("draft_len", 3)
    over.setdefault("max_seq", 64)
    over.setdefault("retry_backoff_s", 0.001)
    return Engine(EngineConfig(model=cfg, draft=dcfg, decode=decode,
                               offload=offload, cache_slots=slots, **over),
                  tparams, dparams)


def _reqs(prompts, **kw):
    return [Request(prompt=p, max_new_tokens=TOK, **kw) for p in prompts]


def _store_cache(ms, slots=8, chaos=None):
    cfg, _, tparams, _, _, _ = ms
    store = HostExpertStore(cfg, tparams, chaos=chaos)
    cache = ExpertCache(slots, store.buffer_shapes(), jnp.float32,
                        table_shape=(store.num_layers, store.num_experts),
                        chaos=chaos)
    return store, cache


# ---------------------------------------------------------------------------
# the injector itself: deterministic, bounded, suppressible
# ---------------------------------------------------------------------------

def test_injector_deterministic_and_streak_bounded():
    """Same seed -> same fault schedule; the consecutive-hard-fault streak
    never exceeds max_consecutive_faults, so a bounded retry budget can
    always out-wait an unlucky run."""
    cfg = ChaosConfig(seed=3, fetch_error_rate=0.6, max_consecutive_faults=2)

    def schedule():
        inj = ChaosInjector(cfg)
        out = []
        for _ in range(200):
            try:
                inj.on_fetch(1)
                out.append(0)
            except ChaosError:
                out.append(1)
        return out

    a, b = schedule(), schedule()
    assert a == b and sum(a) > 0
    streak = best = 0
    for hit in a:
        streak = streak + 1 if hit else 0
        best = max(best, streak)
    assert best <= 2


def test_injector_calm_suppresses_injection_only():
    inj = ChaosInjector(ChaosConfig(seed=0, fetch_error_rate=1.0,
                                    corrupt_rate=1.0))
    with inj.calm():
        for _ in range(20):
            inj.on_fetch(1)            # must not raise
        payload = {"w": np.ones((1, 4), np.float32)}
        assert not inj.maybe_corrupt(payload)
        assert np.all(payload["w"] == 1.0)
    with pytest.raises(ChaosError):
        for _ in range(20):
            inj.on_fetch(1)


# ---------------------------------------------------------------------------
# THE acceptance contract: fault-injected serving is lossless on all 15
# decode x offload combinations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offload", OFFLOAD_POLICIES)
@pytest.mark.parametrize("decode", DECODE_POLICIES)
def test_chaos_serving_lossless_all_combinations(ms, decode, offload):
    """Under the seeded fault schedule (transient errors + spikes +
    corruption + worker kills) two concurrent sessions on a tight cache
    still commit exactly the fault-free greedy reference streams, on every
    decode x offload combination.  Retries, checksum quarantine and the
    degradation ladder absorb every injected fault — latency is the only
    permitted casualty."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, decode=decode, offload=offload, slots=8,
                 max_draft_len=5, chaos=CHAOS) as eng:
        res = eng.serve_all(_reqs(prompts), concurrency=2)
    for r, ref in zip(res, refs):
        assert r.tokens == ref, (decode, offload)
        assert r.finish_reason == "length"


def test_chaos_counters_surface_detection(ms):
    """The resilience counters in counters()/Metrics actually move under
    injection — detection is observable, not silent — and the checksum
    verifier catches every injected corruption."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, slots=8, chaos=CHAOS) as eng:
        res = eng.serve_all(_reqs(prompts), concurrency=2)
        c = eng.runtime.counters()
        inj = eng.runtime.chaos.injected
    assert [r.tokens for r in res] == refs
    assert inj["fetch_errors"] > 0          # the schedule actually fired
    assert c["prefetch_retries"] > 0 or c["prefetch_errors"] > 0
    # every injected corruption was caught by checksum verification —
    # none can have reached the device cache
    assert c["checksum_failures"] >= inj["corruptions"]
    assert c["io_errors"] == 0              # injected faults never escalate
    for k in ("prefetch_errors", "prefetch_retries", "checksum_failures",
              "worker_restarts", "degraded_rounds", "io_errors"):
        assert res[0].metrics[k] >= 0       # ledger carries the new keys


# ---------------------------------------------------------------------------
# prefetcher units: retry, deadline, restart, bounded drain, checksum
# ---------------------------------------------------------------------------

def test_prefetcher_retries_transient_faults(ms):
    """A fetch that fails twice then succeeds completes the task; the retry
    counter records the recovery and the breaker streak resets."""
    store, cache = _store_cache(ms)
    fails = {"n": 0}
    orig = store.fetch

    def flaky(keys):
        if fails["n"] < 2:
            fails["n"] += 1
            raise ChaosError("transient")
        return orig(keys)

    store.fetch = flaky
    pf = Prefetcher(store, cache, mode="worker", retries=3, backoff_s=0.001)
    try:
        task = pf.submit([(0, 0), (0, 1)])
        assert pf.wait_task(task, timeout=10.0)
        assert task.failed is None
        assert cache.contains((0, 0)) and cache.contains((0, 1))
        assert pf.retry_count == 2
        assert pf.error_count == 0
        assert pf.consecutive_failures == 0
    finally:
        pf.stop()


def test_prefetcher_task_deadline_expires_instead_of_retrying_forever(ms):
    """An always-failing task under a tiny per-task deadline fails fast
    (done set, failure recorded) instead of burning its whole retry
    budget."""
    store, cache = _store_cache(ms)

    def always_fail(keys):
        raise ChaosError("down")

    store.fetch = always_fail
    pf = Prefetcher(store, cache, mode="worker", retries=50, backoff_s=0.05,
                    task_timeout_s=0.05)
    try:
        t0 = time.perf_counter()
        task = pf.submit([(0, 0)])
        assert pf.wait_task(task, timeout=10.0)
        assert task.failed is not None
        assert pf.error_count == 1
        # 50 retries x 50ms backoff would be seconds; the deadline cut it
        assert time.perf_counter() - t0 < 2.0
    finally:
        pf.stop()


def test_prefetcher_worker_killed_restarts_and_completes(ms):
    """Chaos worker kills on every second task dequeue: each death hands
    the task back to the queue, the supervisor restarts the worker, and
    every submitted task still completes — inflight accounting never
    strands drain().  (kill_every=1 would kill every dequeue, making
    progress impossible by construction — that schedule is the
    degradation-ladder test's job, not this one's.)"""
    chaos = ChaosInjector(ChaosConfig(seed=0, kill_worker_every=2))
    store, cache = _store_cache(ms, slots=16)
    pf = Prefetcher(store, cache, mode="worker", max_worker_restarts=50,
                    chaos=chaos)
    try:
        tasks = [pf.submit([(0, i)]) for i in range(4)]
        for t in tasks:
            assert pf.wait_task(t, timeout=30.0)
        assert pf.drain(timeout=30.0)
        assert pf.worker_deaths > 0
        assert pf.worker_restarts > 0
        assert all(t.failed is None for t in tasks)
        assert all(cache.contains((0, i)) for i in range(4))
    finally:
        pf.stop()


def test_prefetcher_drain_timeout_returns_instead_of_hanging(ms):
    """drain(timeout=) on a worker stuck inside a long transfer returns
    False promptly (drain_timeouts counted) instead of hanging the caller;
    a later unbounded drain completes once the transfer finishes."""
    store, cache = _store_cache(ms)
    release = threading.Event()
    orig = store.fetch

    def stuck(keys):
        release.wait(timeout=10.0)
        return orig(keys)

    store.fetch = stuck
    pf = Prefetcher(store, cache, mode="worker")
    try:
        pf.submit([(0, 0)])
        t0 = time.perf_counter()
        assert pf.drain(timeout=0.2) is False
        assert time.perf_counter() - t0 < 2.0
        assert pf.drain_timeouts == 1
        release.set()
        assert pf.drain(timeout=10.0)
        assert cache.contains((0, 0))
    finally:
        release.set()
        pf.stop()


def test_checksum_corruption_quarantined_and_refetched(ms):
    """A corrupted staged payload is caught by verification, NEVER inserted
    into the device cache, and the retry refetches it cleanly — the cache
    ends up holding the canonical bytes."""
    chaos = ChaosInjector(ChaosConfig(seed=0, corrupt_rate=1.0,
                                      max_consecutive_faults=1))
    store, cache = _store_cache(ms, chaos=chaos)
    pf = Prefetcher(store, cache, mode="worker", retries=3, backoff_s=0.001,
                    verify=True, chaos=chaos)
    try:
        task = pf.submit([(0, 0)])
        assert pf.wait_task(task, timeout=10.0)
        assert task.failed is None
        assert chaos.injected["corruptions"] >= 1
        assert store.checksum_failures >= 1
        assert pf.checksum_refetches >= 1
        # the slot holds the CANONICAL bytes, not the corrupted ones
        slot = cache.table[(0, 0)]
        clean = store.fetch            # chaos alternates via streak bound;
        with chaos.calm():             # calm() guarantees a clean reference
            want = clean([(0, 0)])
        got = np.asarray(cache.bufs["wu"][slot], np.float32)
        np.testing.assert_allclose(
            got, np.asarray(want["wu"][0], np.float32), rtol=1e-6)
    finally:
        pf.stop()


def test_stop_timed_out_join_keeps_handle_and_refuses_submits(ms):
    """Regression (ISSUE 7 satellite): stop() used to null the thread handle
    even when the join TIMED OUT, so a wedged-but-alive worker could race a
    later inline submit on the same queue/cache.  Now the handle is kept,
    submits are refused while the zombie may still wake, and a later stop()
    can finish the job."""
    store, cache = _store_cache(ms)
    release = threading.Event()
    orig = store.fetch

    def stuck(keys):
        release.wait(timeout=10.0)
        return orig(keys)

    store.fetch = stuck
    pf = Prefetcher(store, cache, mode="worker")
    pf.submit([(0, 0)])
    time.sleep(0.05)                   # let the worker enter the fetch
    assert pf.stop(timeout=0.1) is False
    assert pf._thread is not None      # handle kept: worker still alive
    assert pf.submit([(0, 1)]) is None
    assert pf.refused_submits == 1
    release.set()
    assert pf.stop(timeout=10.0) is True
    assert pf._thread is None


def test_error_ring_is_bounded(ms):
    """Failures land in a bounded ring plus a monotonic count — no unbounded
    error-list growth on a long-running engine (ISSUE 7 satellite)."""
    store, cache = _store_cache(ms)

    def always_fail(keys):
        raise ChaosError("down")

    store.fetch = always_fail
    pf = Prefetcher(store, cache, mode="vanilla", retries=0, error_ring=4)
    for i in range(12):
        pf.submit([(0, i % 8)])
    assert pf.error_count == 12
    assert len(pf.errors) == 4


# ---------------------------------------------------------------------------
# the graceful-degradation ladder
# ---------------------------------------------------------------------------

def test_ladder_degrades_to_on_demand_and_stays_lossless(ms):
    """A permanently dying worker (kill every task, zero restart budget)
    forces the ladder down to on-demand synchronous loading: serving
    completes bit-identically to the reference, degraded rounds are
    counted, and health reports the failed plane."""
    _, _, _, _, prompts, refs = ms
    chaos = ChaosConfig(seed=0, kill_worker_every=1)
    with _engine(ms, slots=8, chaos=chaos, max_worker_restarts=0) as eng:
        res = eng.serve_all(_reqs(prompts), concurrency=2)
        c = eng.runtime.counters()
        health = eng.runtime.health()
    assert [r.tokens for r in res] == refs
    assert all(r.finish_reason == "length" for r in res)
    assert c["degraded_rounds"] > 0
    assert health == "failed"


def test_ladder_recovers_when_health_returns(ms):
    """Degradation is recomputed per round, not latched: opening the
    circuit breaker by hand degrades the engine, and once the cooloff
    passes the same engine serves fast again with the prefetch plane back
    in play."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, slots=8, fail_threshold=1) as eng:
        rt = eng.runtime
        rt.prefetcher.consecutive_failures = 5     # breaker: open
        rt.prefetcher._last_failure_t = time.monotonic()
        rt._check_health()
        assert rt._degraded and rt.health() == "degraded"
        res = eng.serve_all(_reqs(prompts), concurrency=2)
        assert [r.tokens for r in res] == refs
        time.sleep(rt.prefetcher.cooloff_s + 0.05) # half-open: recover
        rt._check_health()
        assert not rt._degraded and rt.health() == "healthy"


def test_real_io_failure_finishes_request_with_io_error(ms):
    """The ladder's last rung: a REAL (non-injected) persistent I/O failure
    on the on-demand path exhausts the synchronous retry budget and ends
    the request with finish_reason="io_error" — no wrong tokens, no hang,
    and the engine survives to serve the next request."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, slots=8, io_retries=1) as eng:
        rt = eng.runtime
        orig = rt.store.fetch

        def down(keys):
            raise OSError("host store unreachable")

        rt.store.fetch = down
        res = eng.serve_all(_reqs(prompts[:1]), concurrency=1)
        assert res[0].finish_reason == "io_error"
        assert len(res[0].tokens) < TOK
        assert rt.counters()["io_errors"] > 0
        rt.store.fetch = orig                      # plane restored
        res2 = eng.serve_all(_reqs(prompts), concurrency=2)
    assert [r.tokens for r in res2] == refs
    assert all(r.finish_reason == "length" for r in res2)


def test_io_error_ends_only_the_failing_session(ms):
    """In a concurrent round, the io_error rung is per-request: the session
    whose loads fail ends with io_error while its batchmate — running from
    the already-warm cache — commits its full reference stream."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, io_retries=0) as eng:         # ample slots
        rt = eng.runtime
        eng.serve_all(_reqs(prompts[:1]))          # warm prompt-0's experts
        orig = rt.store.fetch

        def down(keys):
            raise OSError("host store unreachable")

        rt.store.fetch = down                      # misses now always fail
        res = eng.serve_all(_reqs(prompts), concurrency=2)
        rt.store.fetch = orig
    assert res[0].tokens == refs[0]                # warm batchmate: untouched
    assert res[0].finish_reason == "length"
    assert res[1].finish_reason == "io_error"


# ---------------------------------------------------------------------------
# per-request deadlines
# ---------------------------------------------------------------------------

def test_request_deadline_retires_session_batchmate_completes(ms):
    """A request with an expired wall-clock budget falls out of the batched
    round with finish_reason="deadline"; its batchmate still commits the
    full reference stream."""
    _, _, _, _, prompts, refs = ms
    reqs = [Request(prompt=prompts[0], max_new_tokens=TOK,
                    deadline_s=1e-4),
            Request(prompt=prompts[1], max_new_tokens=TOK)]
    with _engine(ms) as eng:
        res = eng.serve_all(reqs, concurrency=2)
    assert res[0].finish_reason == "deadline"
    assert len(res[0].tokens) < TOK
    assert res[0].tokens == refs[0][:len(res[0].tokens)]  # prefix, not wrong
    assert res[1].tokens == refs[1]
    assert res[1].finish_reason == "length"
