"""Analytical cost model: parameter-count sanity vs published sizes and
FLOP cross-validation against XLA's cost_analysis on real lowerings."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_config
from repro.models.costmodel import (collective_bytes, count_params,
                                    expert_param_bytes, kv_cache_bytes,
                                    roofline_terms, step_flops)
from repro.models.registry import build_model


@pytest.mark.parametrize("arch,expected_b,tol", [
    ("llama3.2-3b", 3.2e9, 0.15),
    ("mixtral-8x7b", 46.7e9, 0.10),
    ("granite-20b", 20e9, 0.25),
    ("command-r-35b", 35e9, 0.20),
    ("nemotron-4-340b", 340e9, 0.15),
    ("deepseek-v2-lite-16b", 15.7e9, 0.25),
    ("mamba2-780m", 0.78e9, 0.25),
    ("zamba2-7b", 7.2e9, 0.35),
])
def test_param_counts_match_published(arch, expected_b, tol):
    cfg = get_config(arch)
    total, active = count_params(cfg)
    assert abs(total - expected_b) / expected_b < tol, f"{arch}: {total/1e9:.2f}B"
    if cfg.family != "hybrid":
        # `active` is the FLOP-side count; weight-shared (hybrid) blocks
        # legitimately exceed `total` because shared params apply many times
        assert active <= total


def test_moe_active_params_much_smaller():
    total, active = count_params(get_config("mixtral-8x7b"))
    assert active < 0.35 * total              # ~13B active of 47B


def test_expert_bytes_matches_paper():
    """Paper §2.2: Mixtral expert ~336 MB (f16/bf16)."""
    b = expert_param_bytes(get_config("mixtral-8x7b"))
    assert abs(b - 336e6) / 336e6 < 0.05
    b = expert_param_bytes(get_config("deepseek-v2-lite-16b"))
    assert abs(b - 16.5e6) / 16.5e6 < 0.10


def test_flops_cross_validated_with_xla():
    """Single-layer dense forward: analytical matmul flops ~= XLA's count."""
    cfg = get_config("llama3.2-3b").reduced(d_model=128, num_heads=8,
                                            num_kv_heads=8, head_dim=16,
                                            d_ff=256, num_layers=1,
                                            vocab_size=512)
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    B, S = 2, 64
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    compiled = jax.jit(lambda p, t: model.forward(p, t)[0]).lower(
        params, tokens).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):              # jax 0.4.x: one entry per device
        ca = ca[0]
    xla_flops = ca["flops"]
    shape = ShapeConfig("t", S, B, "prefill")
    ours = step_flops(cfg, shape)["total"]
    # XLA counts a superset (softmax, norms, rope); ours counts matmuls.
    assert 0.5 * ours < xla_flops < 2.5 * ours, (xla_flops, ours)


def test_train_flops_3x_forward_plus_remat():
    cfg = get_config("llama3.2-3b")
    f_fwd = step_flops(cfg, ShapeConfig("x", 4096, 256, "prefill"))["total"]
    f_train = step_flops(cfg, SHAPES["train_4k"], remat=False)["total"]
    assert abs(f_train / f_fwd - 3.0) < 0.01
    f_remat = step_flops(cfg, SHAPES["train_4k"], remat=True)["total"]
    assert abs(f_remat / f_fwd - 4.0) < 0.01     # full per-layer remat
    useful = step_flops(cfg, SHAPES["train_4k"], remat=True)["useful"]
    assert abs(useful / f_fwd - 3.0) < 0.01      # remat is overhead


def test_decode_flops_scale_with_batch_not_seq():
    cfg = get_config("llama3.2-3b")
    a = step_flops(cfg, ShapeConfig("a", 32768, 128, "decode"))["total"]
    b = step_flops(cfg, ShapeConfig("b", 32768, 64, "decode"))["total"]
    assert abs(a / b - 2.0) < 0.05


def test_swa_caps_kv_cache():
    mix = get_config("mixtral-8x7b")
    b_short = kv_cache_bytes(mix, 1, 4096)
    b_long = kv_cache_bytes(mix, 1, 524288)
    assert b_long <= b_short * 1.1            # rolling window caps growth


def test_mla_cache_much_smaller_than_gqa():
    ds = get_config("deepseek-v2-lite-16b")
    gqa_equiv = dataclasses.replace(ds, use_mla=False)
    assert kv_cache_bytes(ds, 8, 32768) < 0.25 * kv_cache_bytes(gqa_equiv, 8, 32768)


def test_collective_bytes_ep_vs_tp():
    """EP (deepseek, E divisible) adds all-to-all; mixtral (TP experts) has
    none."""
    mesh = {"data": 16, "model": 16}
    ds = collective_bytes(get_config("deepseek-v2-lite-16b"),
                          SHAPES["train_4k"], mesh, "train")
    mx = collective_bytes(get_config("mixtral-8x7b"),
                          SHAPES["train_4k"], mesh, "train")
    assert ds["all_to_all"] > 0
    assert mx["all_to_all"] == 0


def test_roofline_terms_positive_and_dominant():
    mesh = {"data": 16, "model": 16}
    for arch in ("llama3.2-3b", "mixtral-8x7b", "mamba2-780m"):
        for shape in ("train_4k", "decode_32k"):
            r = roofline_terms(get_config(arch), SHAPES[shape], mesh,
                               "train" if shape == "train_4k" else "serve")
            assert r["t_compute"] > 0 and r["t_memory"] > 0
            assert r["dominant"] in ("compute", "memory", "collective")
            assert 0 < r["roofline_fraction"] <= 1.0 + 1e-9
            assert 0 < r["useful_ratio"] <= 1.2
