"""Per-architecture smoke tests (reduced configs) + decode parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, RunConfig
from repro.configs.registry import ASSIGNED, get_config
from repro.models.registry import build_model
from repro.models.train import make_train_step
from repro.optim.optimizer import make_optimizer, warmup_cosine

ALL_ARCHS = sorted(ASSIGNED)


def _fwd(model, cfg, params, tokens, key=None):
    if cfg.family == "encdec":
        frames = jnp.ones((tokens.shape[0], cfg.encoder_seq, cfg.d_model),
                          jnp.dtype(cfg.dtype))
        return model.forward(params, tokens, frames)
    if cfg.family == "vlm":
        pe = jnp.ones((tokens.shape[0], cfg.num_patches, cfg.d_model),
                      jnp.dtype(cfg.dtype))
        return model.forward(params, tokens, patch_embeds=pe)
    return model.forward(params, tokens)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    """Reduced config: one forward pass, output shape + no NaNs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits, aux = _fwd(model, cfg, params, tokens)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one train step on CPU, finite loss, params update."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 2, 100))
    run = RunConfig(microbatch=2)
    step = jax.jit(make_train_step(model, cfg, run, opt))
    B, S = 4, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
    p2, _, metrics = step(params, opt.init(params), batch)
    assert jnp.isfinite(metrics["loss"])
    # at least one leaf changed
    changed = any(bool(jnp.any(a != b)) for a, b in
                  zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


DECODE_ARCHS = ["llama3.2-3b", "mixtral-8x7b", "deepseek-v2-lite-16b",
                "mamba2-780m", "zamba2-7b", "whisper-medium",
                "command-r-35b", "granite-20b", "nemotron-4-340b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """prefill+decode chain == teacher-forced full forward (f32, drop-free)."""
    over = dict(dtype="float32")
    cfg = get_config(arch).reduced(**over)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # train path dropless
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, MAX = 2, 10, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        full, _ = model.forward(params, tokens, frames)
        logits, cache = model.prefill(params, tokens, MAX, frames)
    else:
        full, _ = model.forward(params, tokens)
        logits, cache = model.prefill(params, tokens, MAX)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=2e-3, rtol=1e-3)
    nxt = jax.random.randint(jax.random.PRNGKey(3), (B, 3), 0, cfg.vocab_size)
    toks = tokens
    for i in range(3):
        toks = jnp.concatenate([toks, nxt[:, i:i + 1]], axis=1)
        if cfg.family == "encdec":
            full, _ = model.forward(params, toks, frames)
        else:
            full, _ = model.forward(params, toks)
        lg, cache, _ = model.decode_step(params, cache, nxt[:, i:i + 1], S + i)
        np.testing.assert_allclose(np.asarray(lg[:, -1]), np.asarray(full[:, -1]),
                                   atol=5e-3, rtol=1e-2)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v2-lite-16b",
                                  "llama3.2-3b"])
def test_multi_token_verification_block(arch):
    """Multi-token decode block (SD verification) == teacher-forced forward."""
    cfg = get_config(arch).reduced(dtype="float32")
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    blk = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0, cfg.vocab_size)
    full, _ = model.forward(params, jnp.concatenate([tokens, blk], 1))
    _, cache = model.prefill(params, tokens, 32)
    lg, _, _ = model.decode_step(params, cache, blk, 8)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 8:]),
                               atol=5e-3, rtol=1e-2)


@pytest.mark.slow      # ~30 s rolling-cache soak
def test_swa_rolling_cache_long_decode():
    """Sliding-window ring cache: decoding past the window stays correct."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32", sliding_window=8)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 6
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    _, cache = model.prefill(params, tokens, 64)
    toks = tokens
    for i in range(14):  # run well past the window
        nxt = jax.random.randint(jax.random.PRNGKey(10 + i), (1, 1), 0,
                                 cfg.vocab_size)
        toks = jnp.concatenate([toks, nxt], 1)
        full, _ = model.forward(params, toks)
        lg, cache, _ = model.decode_step(params, cache, nxt, S + i)
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, -1]),
                                   atol=5e-3, rtol=1e-2)


def test_vlm_prefill_decode_with_patches():
    """llava: patch embeddings prefill + text decode parity."""
    cfg = get_config("llava-next-mistral-7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 6
    P_ = cfg.num_patches
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    patches = jax.random.normal(jax.random.PRNGKey(2), (B, P_, cfg.d_model))
    full, _ = model.forward(params, tokens, patch_embeds=patches)
    _, cache = model.prefill(params, tokens, 32, patch_embeds=patches)
    nxt = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0, cfg.vocab_size)
    toks = jnp.concatenate([tokens, nxt], 1)
    full2, _ = model.forward(params, toks, patch_embeds=patches)
    lg, cache, _ = model.decode_step(params, cache, nxt, P_ + S)
    np.testing.assert_allclose(np.asarray(lg[:, -1]), np.asarray(full2[:, -1]),
                               atol=5e-3, rtol=1e-2)


def test_flash_kernel_path_matches_xla_attention():
    """attn_impl='kernel' (Pallas flash attention, interpret mode on CPU)
    produces the same forward as the XLA einsum path through a full model."""
    base = get_config("llama3.2-3b").reduced(dtype="float32", num_layers=2)
    model_x = build_model(base)
    params = model_x.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                base.vocab_size)
    ref, _ = model_x.forward(params, tokens)
    kcfg = dataclasses.replace(base, attn_impl="kernel")
    model_k = build_model(kcfg)
    out, _ = model_k.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    # sliding window too
    swa = dataclasses.replace(base, sliding_window=8)
    swk = dataclasses.replace(swa, attn_impl="kernel")
    ref2, _ = build_model(swa).forward(params, tokens)
    out2, _ = build_model(swk).forward(params, tokens)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2), atol=2e-3,
                               rtol=1e-3)
