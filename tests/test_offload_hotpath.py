"""Device-resident verification hot path: slot-indexed kernel parity, device
page-table consistency (incl. under concurrent prefetch), the ≤2-host-syncs
contract of the fast verify path, prefetcher drain correctness, and the
HostExpertStore staging/strip_experts regressions."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.cache import ExpertCache
from repro.core.engine import Engine, EngineConfig, Request
from repro.core.offload import HostExpertStore
from repro.core.prefetcher import Prefetcher
from repro.core.sd import greedy_generate
from repro.kernels import ref as R
from repro.kernels.cache_moe import _capacity, cache_moe, dispatch_to_slots
from repro.models.registry import build_model


# ---------------------------------------------------------------------------
# slot-indexed cache MoE kernel vs oracle
# ---------------------------------------------------------------------------

def _tol(dtype):
    return 2e-2 if jnp.dtype(dtype) == jnp.bfloat16 else 2e-5


@pytest.mark.slow
@pytest.mark.parametrize("T,k,S,d,f", [
    (5, 2, 6, 32, 64),        # verify-block shaped
    (1, 2, 4, 16, 32),        # single token
    (8, 4, 16, 64, 32),       # wider top-k
    (16, 1, 3, 32, 32),       # k=1, few slots
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cache_moe_parity_swiglu(T, k, S, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (T, d), dtype)
    wg = (jax.random.normal(ks[1], (S, d, f)) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (S, d, f)) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (S, f, d)) * 0.1).astype(dtype)
    slot_ids = jax.random.randint(ks[4], (T, k), -1, S)   # includes misses
    weights = jax.random.uniform(ks[5], (T, k), dtype)
    out = cache_moe(x, slot_ids, weights, wu, wd, wg, interpret=True)
    ref = R.cache_moe_ref(x, slot_ids, weights, wu, wd, wg)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=2e-2)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cache_moe_parity_gelu(dtype):
    """No-wg (gelu up-projection) variant."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    T, k, S, d, f = 6, 2, 5, 32, 64
    x = jax.random.normal(ks[0], (T, d), dtype)
    wu = (jax.random.normal(ks[1], (S, d, f)) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[2], (S, f, d)) * 0.1).astype(dtype)
    slot_ids = jax.random.randint(ks[3], (T, k), -1, S)
    weights = jax.random.uniform(ks[4], (T, k), dtype)
    out = cache_moe(x, slot_ids, weights, wu, wd, None, interpret=True)
    ref = R.cache_moe_ref(x, slot_ids, weights, wu, wd, None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=2e-2)


@pytest.mark.slow
def test_cache_moe_masked_and_zero_weight_choices():
    """slot < 0 and weight == 0 choices contribute exactly zero; duplicate
    slots for one token accumulate."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    T, k, S, d, f = 5, 2, 6, 32, 32
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (S, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (S, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (S, f, d)) * 0.1
    weights = jax.random.uniform(ks[4], (T, k))
    # all masked -> exact zero
    all_miss = jnp.full((T, k), -1, jnp.int32)
    out = cache_moe(x, all_miss, weights, wu, wd, wg, interpret=True)
    assert bool(jnp.all(out == 0))
    # zero weight kills the choice even when the slot is valid
    si = jnp.array([[0, 0], [5, 5], [2, 3], [1, -1], [4, 2]], jnp.int32)
    w0 = weights.at[:, 1].set(0.0)
    out = cache_moe(x, si, w0, wu, wd, wg, interpret=True)
    only_first = cache_moe(x, si.at[:, 1].set(-1), weights, wu, wd, wg,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(only_first),
                               atol=1e-5, rtol=1e-4)
    # duplicate-slot parity vs oracle
    ref = R.cache_moe_ref(x, si, weights, wu, wd, wg)
    out = cache_moe(x, si, weights, wu, wd, wg, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)


def test_cache_moe_ref_matches_dense_loop():
    """The oracle itself vs a naive per-choice python loop."""
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    T, k, S, d, f = 4, 3, 5, 16, 24
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (S, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (S, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (S, f, d)) * 0.1
    slot_ids = np.asarray(jax.random.randint(ks[4], (T, k), -1, S))
    weights = np.asarray(jax.random.uniform(ks[5], (T, k)))
    want = np.zeros((T, d), np.float32)
    for t in range(T):
        for c in range(k):
            s = int(slot_ids[t, c])
            if s < 0:
                continue
            h = jax.nn.silu(x[t] @ wg[s]) * (x[t] @ wu[s])
            want[t] += weights[t, c] * np.asarray(h @ wd[s])
    got = R.cache_moe_ref(x, jnp.asarray(slot_ids), jnp.asarray(weights),
                          wu, wd, wg)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-3)


def test_dispatch_to_slots_no_drops():
    """Capacity is sized to the worst case — every valid choice lands."""
    T, k, S = 7, 2, 4
    C = _capacity(T * k, 128)
    rng = np.random.default_rng(0)
    slot_ids = jnp.asarray(rng.integers(-1, S, size=(T, k)), jnp.int32)
    idx, valid, pos = dispatch_to_slots(slot_ids, S, C)
    n_valid = int((np.asarray(slot_ids) >= 0).sum())
    assert int(np.asarray(valid).sum()) == n_valid
    posn = np.asarray(pos)
    assert ((posn < C) == (np.asarray(slot_ids) >= 0)).all()


# ---------------------------------------------------------------------------
# device page-table mirror
# ---------------------------------------------------------------------------

def _mk_cache(slots=4, L=3, E=5):
    cache = ExpertCache(slots, {"w": (2, 2)}, jnp.float32, table_shape=(L, E))
    arrays = {"w": np.ones((1, 2, 2), np.float32)}
    return cache, arrays, L, E


def test_table_array_tracks_inserts_and_evictions():
    cache, arrays, L, E = _mk_cache()
    rng = np.random.default_rng(0)
    for _ in range(200):
        key = (int(rng.integers(L)), int(rng.integers(E)))
        if rng.random() < 0.5:
            cache.insert([key], arrays)
        else:
            cache.lookup([key])
        assert cache.check_invariants()   # includes table_dev == table
    tdev = np.asarray(cache.table_dev)
    for (l, e), s in cache.table.items():
        assert tdev[l, e] == s
    assert (tdev >= 0).sum() == len(cache.table)


def test_table_scatter_trace_count_bounded():
    """Regression (ROADMAP open item): the jitted page-table scatter used to
    retrace per distinct scatter length, so trace-cache growth scaled with
    the number of distinct insert+eviction sizes.  Lengths are now padded to
    powers of two — many distinct sizes may compile at most one executable
    per bucket — and the padding (a repeated final triple) must keep the
    device mirror exact."""
    cache, _, L, E = _mk_cache(slots=8, L=6, E=9)
    rng = np.random.default_rng(0)
    seen_lengths = set()
    for _ in range(60):
        n = int(rng.integers(1, 7))
        keys = [(int(rng.integers(L)), int(rng.integers(E)))
                for _ in range(n)]
        arrays = {"w": np.ones((len(keys), 2, 2), np.float32)}
        cache.insert(keys, arrays)
        seen_lengths.add(n)
        assert cache.check_invariants()    # padding kept table_dev exact
    assert len(seen_lengths) >= 5, "sweep failed to vary insert sizes"
    # evictions extend scatter lengths further; buckets {1,2,4,8,16} bound
    # the executables regardless
    assert cache.table_scatter_traces <= 5, \
        f"scatter retraced per length: {cache.table_scatter_traces} traces"


def test_table_array_consistent_under_concurrent_prefetch():
    """Prefetch worker + compute loop hammer the cache concurrently; the
    invariants (incl. the device table mirror) must hold throughout."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams)
    L, E = store.num_layers, store.num_experts
    cache = ExpertCache(6, store.buffer_shapes(), jnp.float32,
                        table_shape=(L, E))
    pf = Prefetcher(store, cache, mode="worker", batched=True)
    stop = threading.Event()
    errs = []

    def compute_loop():
        rng = np.random.default_rng(1)
        try:
            while not stop.is_set():
                keys = [(int(rng.integers(L)), int(rng.integers(E)))
                        for _ in range(3)]
                hits, misses = cache.lookup(keys)
                if misses:
                    cache.insert(misses, store.fetch(misses), mark_used=True)
                with cache.lock:
                    assert cache.check_invariants()
        except Exception as e:  # surface across the thread boundary
            errs.append(e)

    t = threading.Thread(target=compute_loop)
    t.start()
    rng = np.random.default_rng(2)
    for _ in range(60):
        keys = [(int(rng.integers(L)), int(rng.integers(E)))
                for _ in range(4)]
        pf.submit(keys)
    pf.drain()
    stop.set()
    t.join(timeout=30)
    pf.stop()
    assert not errs, errs
    assert not pf.errors, pf.errors
    assert cache.check_invariants()


# ---------------------------------------------------------------------------
# ≤2 host syncs per verify block (fast path) + losslessness
# ---------------------------------------------------------------------------

def _toy_engine(policy="spmoe", slots=6, draft_len=3, precompile=True):
    """Unified-API engine; ``eng.runtime`` is the OffloadEngine underneath
    (the hot-path internals these tests spy on)."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    target = build_model(cfg)
    draft = build_model(dcfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = draft.init(jax.random.PRNGKey(1))
    eng = Engine(EngineConfig(model=cfg, draft=dcfg, decode="sd",
                              offload=policy, cache_slots=slots,
                              draft_len=draft_len, max_seq=64,
                              precompile=precompile),
                 tparams, dparams)
    return cfg, target, tparams, eng


def test_fast_path_two_syncs_per_block_and_lossless():
    """With an ample cache the verify fast path arms; each fast verify block
    performs exactly ONE host sync inside _verify_block (the all_hit scalar)
    — with the accept/reject readback in the decode loop that is the ≤2
    contract — and the output still exactly matches plain greedy decoding."""
    cfg, target, tparams, eng = _toy_engine(
        slots=eng_slots_all(), draft_len=3)
    rt = eng.runtime
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                                cfg.vocab_size)
    per_block = []
    orig_vb = rt._verify_block

    def spy_vb(tokens, pos, tcache):
        before_sync, before_fast = rt.host_syncs, rt.fast_blocks
        result = orig_vb(tokens, pos, tcache)
        per_block.append((rt.host_syncs - before_sync,
                          rt.fast_blocks > before_fast))
        return result

    rt._verify_block = spy_vb
    ref = greedy_generate(target, tparams, prompt, 16, 64)
    res = eng.submit(Request(prompt=prompt, max_new_tokens=16))
    out, stats = res.token_array(), res.metrics
    eng.close()
    assert out.tolist() == ref.tolist()
    fast = [s for s, is_fast in per_block if is_fast]
    assert fast, "fast path never engaged — check adaptive arming"
    assert max(fast) == 1, f"fast verify block synced more than once: {per_block}"
    # the only other per-iteration readback is the accept/reject argmax
    assert stats["fast_blocks"] == len(fast)
    assert stats["fast_fallbacks"] == 0


def eng_slots_all():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    return cfg.num_moe_layers * cfg.num_experts


def test_fast_path_fallback_is_lossless_when_cache_too_small():
    """Tight cache: fast path may mispredict availability; fallback must
    keep exact losslessness."""
    cfg, target, tparams, eng = _toy_engine(slots=6, draft_len=3)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                                cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 12, 64)
    res = eng.submit(Request(prompt=prompt, max_new_tokens=12))
    out, stats = res.token_array(), res.metrics
    eng.close()
    assert out.tolist() == ref.tolist()
    assert stats["on_demand_loads"] > 0      # the tight cache did miss


def test_hot_path_never_reads_resident_expert_weights():
    """The verify paths must read expert weights only from the cache slot
    buffers: zeroing the resident copies after engine construction must not
    change the output.  precompile=False so the fast path is traced AFTER
    the zeroing — an init-time trace would bake the real weights in as
    constants and mask exactly the regression this test exists to catch."""
    cfg, target, tparams, eng = _toy_engine(slots=eng_slots_all(),
                                            precompile=False)
    rt = eng.runtime
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                                cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 10, 64)
    # wipe the device-resident expert tensors (store already copied them)
    for n in rt.store.names:
        rt.tparams["layers"]["moe"][n] = \
            jnp.zeros_like(rt.tparams["layers"]["moe"][n])
    res = eng.submit(Request(prompt=prompt, max_new_tokens=10))
    eng.close()
    assert res.tokens == ref.tolist()


# ---------------------------------------------------------------------------
# prefetcher drain
# ---------------------------------------------------------------------------

class _SlowStore(HostExpertStore):
    def fetch(self, keys):
        time.sleep(0.05)                  # expose the popped-mid-execute race
        return super().fetch(keys)


def test_drain_waits_for_inflight_tasks():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = _SlowStore(cfg, tparams)
    cache = ExpertCache(16, store.buffer_shapes(), jnp.float32,
                        table_shape=(store.num_layers, store.num_experts))
    pf = Prefetcher(store, cache, mode="worker", batched=True)
    keys = [(0, 0), (0, 1), (1, 2), (2, 3), (3, 4)]
    for k in keys:
        pf.submit([k])
    pf.drain()                            # must cover mid-_execute tasks
    assert all(cache.contains(k) for k in keys)
    assert pf.loaded_count == len(keys)
    pf.stop()


def test_drain_no_busy_wait_completes_quickly_when_idle():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams)
    cache = ExpertCache(4, store.buffer_shapes(), jnp.float32)
    pf = Prefetcher(store, cache, mode="worker")
    t0 = time.perf_counter()
    pf.drain()
    assert time.perf_counter() - t0 < 1.0
    pf.stop()


# ---------------------------------------------------------------------------
# HostExpertStore: staging fetch + strip_experts isolation
# ---------------------------------------------------------------------------

def test_fetch_staging_survives_double_buffering():
    """A fetched batch stays valid while the NEXT fetch writes the other
    staging buffer (the overlap contract insert relies on)."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams)
    a = store.fetch([(0, 0), (1, 1)])
    snap = {n: arr.copy() for n, arr in a.items()}
    b = store.fetch([(2, 2), (3, 3), (0, 5)])   # other buffer
    for n in store.names:
        np.testing.assert_array_equal(a[n], snap[n])
        np.testing.assert_array_equal(b[n][0], store._store[n][2, 2])
    # contents correct against the raw store
    np.testing.assert_array_equal(a[store.names[0]][1],
                                  store._store[store.names[0]][1, 1])


def test_fetch_staging_is_thread_local():
    """Concurrent fetch from the prefetch worker and the compute loop must
    not overwrite each other's staged batches (regression: a shared staging
    ring let one thread's gather corrupt the other's in-flight batch)."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams)
    stop = threading.Event()
    bad = []

    def hammer():
        while not stop.is_set():
            store.fetch([(0, 0), (1, 1), (2, 2)])

    t = threading.Thread(target=hammer)
    t.start()
    try:
        for _ in range(200):
            got = store.fetch([(3, 3), (0, 5)])
            time.sleep(0.0005)            # hold the view across other-thread fetches
            for n in store.names:
                if not np.array_equal(got[n][0], store._store[n][3, 3]):
                    bad.append(n)
    finally:
        stop.set()
        t.join(timeout=10)
    assert not bad, f"staged batch corrupted by concurrent fetch: {bad[:3]}"


def test_fetch_grows_staging_for_large_batches():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams, staging_batch=2)
    keys = [(l, e) for l in range(store.num_layers)
            for e in range(store.num_experts)][:20]
    got = store.fetch(keys)
    for i, (l, e) in enumerate(keys):
        np.testing.assert_array_equal(got[store.names[0]][i],
                                      store._store[store.names[0]][l, e])


def test_strip_experts_does_not_mutate_original():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    store = HostExpertStore(cfg, tparams)
    shapes_before = {n: tparams["layers"]["moe"][n].shape
                     for n in store.names}
    out = store.strip_experts(tparams)
    for n in store.names:
        assert tparams["layers"]["moe"][n].shape == shapes_before[n]
        assert out["layers"]["moe"][n].shape == (0,)
    # isolation in the other direction too: mutating the copy's dicts must
    # not leak into the original
    out["layers"]["moe"]["gate"] = None
    assert tparams["layers"]["moe"]["gate"] is not None
