"""End-to-end system tests: training convergence, checkpoint/restart
equivalence, fault-tolerant supervision with elastic re-planning, and the
full SP-MoE serving path."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_draft_for
from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.core.engine import Engine, EngineConfig, Request
from repro.core.sd import greedy_generate
from repro.launch.train import Trainer
from repro.models.registry import build_model


def _tiny_trainer(ckpt_dir=None, arch="llama3.2-3b", grad_compress=False):
    cfg = get_config(arch).reduced(num_layers=2, d_model=32, num_heads=2,
                                   num_kv_heads=2, head_dim=16, d_ff=64,
                                   vocab_size=128)
    shape = ShapeConfig("tiny", 32, 4, "train")
    run = RunConfig(warmup_steps=2, total_steps=40, learning_rate=3e-3)
    return Trainer(cfg, shape, run, ckpt_dir=ckpt_dir,
                   grad_compress=grad_compress), cfg


def test_training_loss_decreases():
    tr, _ = _tiny_trainer()
    _, losses = tr.train(25, log_every=0)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, (first, last)


@pytest.mark.slow      # training soaks: tier-1 only, not API signal
def test_checkpoint_restart_resumes_identically():
    """Train 10 straight vs train 5 + restart + 5: identical params (data
    pipeline is restart-stable, checkpoint is exact)."""
    with tempfile.TemporaryDirectory() as d1:
        tr, _ = _tiny_trainer()
        state_a, _ = tr.train(10, log_every=0)
        tr2, _ = _tiny_trainer(ckpt_dir=d1)
        tr2.train(5, ckpt_every=5, log_every=0)
        tr2.ckpt.wait()
        tr3, _ = _tiny_trainer(ckpt_dir=d1)
        state_b, _ = tr3.train(10, log_every=0)
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


@pytest.mark.slow
def test_training_with_grad_compression_converges():
    tr, _ = _tiny_trainer(grad_compress=True)
    _, losses = tr.train(25, log_every=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.slow
def test_supervised_training_with_failure_and_restart():
    """Injected failure mid-run; restart restores from checkpoint and
    completes the remaining steps."""
    with tempfile.TemporaryDirectory() as d:
        tr, _ = _tiny_trainer(ckpt_dir=d)
        with pytest.raises(RuntimeError):
            tr.train(20, ckpt_every=4, fail_at=9, log_every=0)
        tr.ckpt.wait()
        assert tr.ckpt.latest_step() == 8
        tr2, _ = _tiny_trainer(ckpt_dir=d)
        _, losses = tr2.train(20, ckpt_every=4, log_every=0)
        assert len(losses) == 12              # resumed from step 8


def test_spmoe_serving_end_to_end():
    """Full paper pipeline on a reduced mixtral through the unified request
    API: draft -> predict -> prefetch -> cached verification; lossless
    output + prefetching active."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 16, 64)
    config = EngineConfig(model=cfg, draft=dcfg, decode="sd", offload="spmoe",
                          cache_slots=8, draft_len=4, max_seq=64)
    with Engine(config, tparams) as eng:
        res = eng.submit(Request(prompt=prompt, max_new_tokens=16))
    assert res.tokens == ref.tolist()
    stats = res.metrics
    assert stats["prefetched"] > 0
    assert 0 <= stats["hit_rate"] <= 1
    assert stats["cutoff_layer"] >= 0
