"""Calibrated simulator: the paper's orderings must reproduce."""
import numpy as np
import pytest

from repro.core.simulator import SIM_MODELS, SimConfig, Simulator, simulate


def _tpot(model, policy, seeds=(0, 1, 2), **kw):
    return float(np.mean([simulate(model, policy=policy, seed=s,
                                   out_tokens=60, **kw).tpot for s in seeds]))


@pytest.mark.parametrize("model", list(SIM_MODELS))
def test_spmoe_beats_all_baselines(model):
    sp = _tpot(model, "spmoe")
    for base in ("on-demand", "moe-infinity", "adapmoe"):
        assert sp < _tpot(model, base), (model, base)


def test_on_demand_is_worst():
    for model in SIM_MODELS:
        mo = _tpot(model, "on-demand")
        for other in ("moe-infinity", "adapmoe", "spmoe"):
            assert _tpot(model, other) < mo


def test_hit_rate_pattern_table3():
    """AdapMoE hit > SP-MoE hit for mixtral (yet SP-MoE wins on TPOT);
    SP-MoE hit rate is the highest for deepseek."""
    def hit(model, policy):
        return float(np.mean([simulate(model, policy=policy, seed=s,
                                       out_tokens=60).hit_rate
                              for s in (0, 1, 2)]))
    assert hit("mixtral-8x7b", "adapmoe") > hit("mixtral-8x7b", "spmoe")
    ds = "deepseek-v2-lite-16b"
    sp = hit(ds, "spmoe")
    for other in ("on-demand", "moe-infinity", "adapmoe"):
        assert sp > hit(ds, other)


def test_cutoff_u_shape_mixtral_monotone_deepseek():
    """Fig 14: U-shape for mixtral (best strictly between 0 and max), and
    deepseek improves monotonically (within noise) with depth."""
    def sweep(model, cuts):
        return [float(np.mean([simulate(model, policy="spmoe", cutoff=c,
                                        seed=s, out_tokens=60).tpot
                               for s in (0, 1, 2)])) for c in cuts]
    mix = sweep("mixtral-8x7b", [0, 10, 20, 31])
    assert min(mix[1], mix[2]) < mix[0]       # improves from 0
    assert min(mix[1], mix[2]) < mix[3]       # over-prefetch hurts (U-shape)
    ds = sweep("deepseek-v2-lite-16b", [0, 12, 25])
    assert ds[2] < ds[0]
    assert ds[1] < ds[0]


def test_ablation_ordering_fig12():
    """baseline > +vp > +wp >= +wp+b (TPOT decreasing)."""
    base = _tpot("mixtral-8x7b", "on-demand")
    vp = _tpot("mixtral-8x7b", "spmoe", worker_prefetch=False, batched_io=False)
    wp = _tpot("mixtral-8x7b", "spmoe", worker_prefetch=True, batched_io=False)
    wpb = _tpot("mixtral-8x7b", "spmoe", worker_prefetch=True, batched_io=True)
    assert vp < base
    assert wp < vp
    assert wpb <= wp * 1.02


def test_draft_len_narrows_gap_fig13():
    """Longer drafts: SP-MoE stays (near-)fastest at every draft length, and
    the gap to the on-demand baseline narrows from N=1 to N=4 (Fig. 13 —
    'performance gaps narrow slightly with longer draft token length')."""
    seeds = tuple(range(5))
    gaps = []
    for n in (1, 2, 4):
        od = _tpot("mixtral-8x7b", "on-demand", seeds=seeds, draft_len=n)
        ad = _tpot("mixtral-8x7b", "adapmoe", seeds=seeds, draft_len=n)
        sp = _tpot("mixtral-8x7b", "spmoe", seeds=seeds, draft_len=n)
        assert sp < od
        assert sp < ad * 1.05          # within noise of the best baseline
        gaps.append(od / sp)
    assert gaps[2] < gaps[0]           # narrowing


def test_memory_sweep_fig11():
    """More GPU memory -> lower (or equal) TPOT for SP-MoE; SP-MoE lowest
    under the tightest budget."""
    tp = [_tpot("deepseek-v2-lite-16b", "spmoe", gpu_mem_gb=g)
          for g in (10, 24, 39)]
    assert tp[2] <= tp[0] * 1.05
    for pol in ("on-demand", "moe-infinity", "adapmoe"):
        assert _tpot("deepseek-v2-lite-16b", pol, gpu_mem_gb=10) >= tp[0] * 0.95


def test_sd_speedup_vs_no_sd():
    """SD itself reduces TPOT (the premise of the paper)."""
    sd = _tpot("mixtral-8x7b", "spmoe", draft_len=4)
    no_sd = _tpot("mixtral-8x7b", "spmoe", sd_enabled=False)
    assert sd < no_sd


def test_determinism():
    a = simulate("mixtral-8x7b", policy="spmoe", seed=7, out_tokens=40)
    b = simulate("mixtral-8x7b", policy="spmoe", seed=7, out_tokens=40)
    assert a.tpot == b.tpot and a.hit_rate == b.hit_rate
