"""MoE routing correctness + dispatch property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models.moe import (_dispatch_indices, gate_topk, init_moe,
                              moe_global, moe_grouped, moe_ref)


def _cfg(arch="mixtral-8x7b", **over):
    cfg = get_config(arch).reduced(dtype="float32")
    return dataclasses.replace(cfg, **over)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v2-lite-16b",
                                  "phi-3.5-moe"])
def test_routing_paths_match_oracle(arch):
    cfg = _cfg(arch, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    ref = moe_ref(p, x, cfg)
    yg, _ = moe_grouped(p, x, cfg)
    ygl, _ = moe_global(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ygl), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_global_path_is_dropless_under_skew():
    """Even with every token picking the same expert, moe_global drops none."""
    cfg = _cfg(capacity_factor=0.5)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # gate weights forced so expert 0/1 always win
    gate = np.zeros((cfg.d_model, cfg.num_experts), np.float32)
    gate[:, 0] = 5.0
    gate[:, 1] = 4.0
    p = dict(p, gate=jnp.asarray(gate))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    ref = moe_ref(p, x, cfg)
    y, _ = moe_global(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.slow      # ~20 s dispatch property soak
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 16), st.integers(1, 4),
       st.integers(2, 32))
def test_dispatch_indices_properties(seed, E, k, T):
    """Dispatch invariants: every (token, choice) either lands in a unique
    (expert, slot) or is dropped; slots stay within capacity; valid mask
    matches; no two choices share a slot."""
    k = min(k, E)
    rng = np.random.default_rng(seed)
    ids_np = np.stack([rng.choice(E, size=k, replace=False) for _ in range(T)])
    C = max(1, int(np.ceil(T * k / E)))
    ids = jnp.asarray(ids_np, jnp.int32)
    idx, valid, slot = jax.tree.map(np.asarray, _dispatch_indices(ids, E, C))
    # every valid (e, c) slot holds a token that actually chose e
    for e in range(E):
        for c in range(C):
            if valid[e, c]:
                assert e in ids_np[idx[e, c]]
    # slot mapping consistency: choice (t, j) with slot < C maps back to t
    for t in range(T):
        for j in range(k):
            s = slot[t, j]
            if s < C:
                assert valid[ids_np[t, j], s]
                assert idx[ids_np[t, j], s] == t
    # capacity respected: counts per expert <= C, no duplicate tokens per slot
    for e in range(E):
        used = [idx[e, c] for c in range(C) if valid[e, c]]
        assert len(used) == len(set(used))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_gate_topk_normalized(seed):
    cfg = _cfg()
    gate = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31),
                             (cfg.d_model, cfg.num_experts))
    x = jax.random.normal(jax.random.PRNGKey((seed + 1) % 2 ** 31),
                          (4, cfg.d_model))
    w, ids, probs, aux = gate_topk(gate, x, cfg.num_experts_per_tok)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert np.asarray(probs).min() >= 0
    assert float(aux) >= 1.0 - 1e-3   # switch aux loss lower bound is 1
    # ids within range and unique per token
    ids_np = np.asarray(ids)
    assert ids_np.min() >= 0 and ids_np.max() < cfg.num_experts
    for row in ids_np:
        assert len(set(row.tolist())) == len(row)
