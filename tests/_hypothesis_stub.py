"""Minimal stand-in for the ``hypothesis`` package (not installed in this
container).  Implements just the API surface the test-suite uses — ``given``,
``settings`` and the ``integers / floats / sampled_from / lists / tuples``
strategies — as a deterministic seeded random sampler.

Semantics: ``@given(...)`` reruns the test body ``max_examples`` times with
freshly drawn values (seeded per test name, so failures are reproducible).
No shrinking, no database — on failure the offending drawn values are shown
in the assertion context.

Activated by tests/conftest.py only when the real package is missing, via
``sys.modules`` registration, so installing real hypothesis transparently
takes over.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(seq):
    elems = list(seq)
    return _Strategy(lambda rng: rng.choice(elems))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def lists(elem: _Strategy, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10
    return _Strategy(
        lambda rng: [elem.example(rng) for _ in range(rng.randint(min_size, hi))])


def tuples(*elems: _Strategy):
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def just(value):
    return _Strategy(lambda rng: value)


_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator; records max_examples on the (already ``given``-wrapped) fn."""
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = [s.example(rng) for s in strategies]
                kdrawn = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kdrawn, **kwargs)
                except Exception as e:  # annotate with the failing example
                    raise AssertionError(
                        f"hypothesis-stub example {i}/{n} failed: "
                        f"args={drawn} kwargs={kdrawn}: {e}") from e
        # the drawn parameters are filled here, not by pytest: hide them so
        # pytest doesn't try to resolve them as fixtures (wraps propagates
        # __wrapped__, which inspect.signature would follow otherwise)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def install():
    """Register this module as ``hypothesis`` (+``hypothesis.strategies``)."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "lists", "tuples",
                 "booleans", "just"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
