"""Speculative decoding: LOSSLESSNESS (the core property — SD output is
bit-identical to target-only greedy decoding) + acceptance behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.sd import greedy_generate, make_sd_step, sd_generate
from repro.models.registry import build_model


def _setup(arch, seed=0, draft_seed=1):
    cfg = get_config(arch).reduced(dtype="float32")
    dcfg = make_draft_for(cfg)
    target = build_model(cfg)
    draft = build_model(dcfg)
    tparams = target.init(jax.random.PRNGKey(seed))
    dparams = draft.init(jax.random.PRNGKey(draft_seed))
    return cfg, dcfg, target, draft, tparams, dparams


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v2-lite-16b",
                                  "llama3.2-3b"])
@pytest.mark.parametrize("draft_len", [1, 3, 5])
def test_sd_lossless(arch, draft_len):
    cfg, dcfg, target, draft, tparams, dparams = _setup(arch)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 20, 64)
    out, stats = sd_generate(draft, target, dparams, tparams, prompt, 20,
                             draft_len, 64)
    assert out.tolist() == ref.tolist(), stats


@pytest.mark.slow          # ~40 s property soak; test_sd_lossless covers API
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_sd_lossless_property(seed, draft_len):
    """Losslessness holds for ANY draft model (even adversarial/random)."""
    cfg, dcfg, target, draft, tparams, dparams = _setup(
        "mixtral-8x7b", seed=seed % 7, draft_seed=seed)
    prompt = jax.random.randint(jax.random.PRNGKey(seed), (1, 5), 0,
                                cfg.vocab_size)
    ref = greedy_generate(target, tparams, prompt, 12, 48)
    out, _ = sd_generate(draft, target, dparams, tparams, prompt, 12,
                         draft_len, 48)
    assert out.tolist() == ref.tolist()


def test_sd_perfect_draft_accepts_everything():
    """Draft == target -> every draft token is accepted (acceptance rate 1),
    and SD emits draft_len+1 tokens per iteration."""
    cfg = get_config("llama3.2-3b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab_size)
    out, stats = sd_generate(model, model, params, params, prompt, 16, 4, 64)
    ref = greedy_generate(model, params, prompt, 16, 64)
    assert out.tolist() == ref.tolist()
    assert stats["acceptance_rate"] > 0.99
    assert stats["tokens_per_iteration"] >= 4.9


def test_sd_step_emits_within_bounds():
    cfg, dcfg, target, draft, tparams, dparams = _setup("llama3.2-3b")
    N = 4
    step = jax.jit(make_sd_step(draft, target, N))
    _, tcache = target.prefill(tparams, jnp.zeros((1, 4), jnp.int32), 32)
    _, dcache = draft.prefill(dparams, jnp.zeros((1, 4), jnp.int32), 32)
    cur = jnp.array([[1]], jnp.int32)
    res = step(dparams, tparams, dcache, tcache, cur, jnp.int32(4))
    n = int(res.n_emitted)
    assert 1 <= n <= N + 1
    assert int(res.n_accepted) == n - 1
    toks = np.asarray(res.tokens)
    assert np.all(toks[:n] >= 0)
    assert np.all(toks[n:] == -1)


def test_adaptive_draft_length_lossless_and_adapts():
    """Beyond-paper controller: lossless for any schedule; grows N with a
    perfect draft, shrinks with a useless one."""
    from repro.core.sd import sd_generate_adaptive
    cfg = get_config("llama3.2-3b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                                cfg.vocab_size)
    ref = greedy_generate(model, params, prompt, 20, 96)
    # perfect draft (same model): N should grow toward max
    out, stats = sd_generate_adaptive(model, model, params, params, prompt,
                                      20, 96, min_len=1, max_len=6)
    assert out.tolist() == ref.tolist()
    assert stats["final_draft_len"] >= 4
    # useless draft (random weights): N stays at the floor, still lossless
    dcfg = make_draft_for(cfg)
    draft = build_model(dcfg)
    dparams = draft.init(jax.random.PRNGKey(9))
    out2, stats2 = sd_generate_adaptive(draft, model, dparams, params, prompt,
                                        20, 96, min_len=1, max_len=6)
    assert out2.tolist() == ref.tolist()
    assert stats2["mean_draft_len"] <= 2.5
