"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py fabricates 512 devices."""
import dataclasses

try:                                    # the container has no hypothesis;
    import hypothesis  # noqa: F401     # fall back to the deterministic stub
except ModuleNotFoundError:
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax
import pytest

from repro.configs.registry import get_config


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_f32(arch: str, **over):
    cfg = get_config(arch).reduced(dtype="float32", **over)
    return cfg


def make_draft_for(cfg):
    """Dense (or shallow) draft config for SD tests."""
    if cfg.is_moe:
        return dataclasses.replace(cfg, num_experts=0, num_experts_per_tok=0,
                                   num_shared_experts=0, first_dense_layers=0,
                                   name=cfg.name + "-draft")
    return dataclasses.replace(cfg, num_layers=max(2, cfg.num_layers // 2),
                               name=cfg.name + "-draft")
