"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py fabricates 512 devices."""
try:                                    # the container has no hypothesis;
    import hypothesis  # noqa: F401     # fall back to the deterministic stub
except ModuleNotFoundError:
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax
import pytest

from repro.configs.registry import get_config


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_f32(arch: str, **over):
    cfg = get_config(arch).reduced(dtype="float32", **over)
    return cfg


def make_draft_for(cfg):
    """Dense (or shallow) draft config for SD tests — the engine's own
    default derivation (single source of truth)."""
    from repro.core.engine import derive_draft_config
    return derive_draft_config(cfg)
