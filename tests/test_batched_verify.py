"""Batched cross-session verification (the fused Engine.serve rounds):
every scheduling round gathers the ready sessions' draft blocks into ONE
``_verify_fast_batched`` dispatch — one routing pass, one page-table gather,
one cache_moe launch, ≤2 host syncs per ROUND (not per session).  Asserted
here: bit-identical losslessness vs solo serving across all 15 decode x
offload combinations under ragged draft lengths, per-session miss fallback
that leaves batchmates on the fast path, the ≤2-syncs-per-round contract,
one fused launch per all-hit round, and a hypothesis property sweep over
schedules."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hs

from conftest import make_draft_for
from repro.configs.registry import get_config
from repro.core.engine import (DECODE_POLICIES, OFFLOAD_POLICIES, Engine,
                               EngineConfig, Request)
from repro.core.sd import greedy_generate
from repro.models.registry import build_model

TOK = 10
PLENS = (4, 6, 9)        # ragged prompts: ragged prefills AND, with
                         # sd-adaptive, ragged per-session draft lengths

_MS = None


def _ms():
    """Module-memoized target/draft params, three ragged prompts, greedy
    refs.  A plain function (not a fixture) so the hypothesis property test
    can use it too — the stub's @given hides the signature from pytest's
    fixture resolution."""
    global _MS
    if _MS is None:
        cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
        dcfg = make_draft_for(cfg)
        target = build_model(cfg)
        tparams = target.init(jax.random.PRNGKey(0))
        dparams = build_model(dcfg).init(jax.random.PRNGKey(1))
        prompts = [jax.random.randint(jax.random.PRNGKey(2 + i), (1, n), 0,
                                      cfg.vocab_size)
                   for i, n in enumerate(PLENS)]
        refs = [greedy_generate(target, tparams, p, TOK, 64).tolist()
                for p in prompts]
        _MS = (cfg, dcfg, tparams, dparams, prompts, refs)
    return _MS


@pytest.fixture(scope="module")
def ms():
    return _ms()


def _engine(ms, decode="sd", offload="spmoe", slots=None, **over):
    cfg, dcfg, tparams, dparams, _, _ = ms
    if slots is None:
        slots = cfg.num_moe_layers * cfg.num_experts    # ample
    over.setdefault("draft_len", 3)
    over.setdefault("max_seq", 64)
    return Engine(EngineConfig(model=cfg, draft=dcfg, decode=decode,
                               offload=offload, cache_slots=slots, **over),
                  tparams, dparams)


def _reqs(prompts, n=TOK):
    return [Request(prompt=p, max_new_tokens=n) for p in prompts]


# ---------------------------------------------------------------------------
# batched rounds are lossless — all 15 decode x offload combinations,
# ragged prompts, tight cache (mixed hit/miss + per-session fallbacks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offload", OFFLOAD_POLICIES)
@pytest.mark.parametrize("decode", DECODE_POLICIES)
def test_batched_rounds_lossless_all_combinations(ms, decode, offload):
    """The acceptance contract: two ragged sessions fused per round emit the
    token stream of serving each alone (the solo greedy reference) on all 15
    combinations.  A tight cache keeps the offload combos under miss and
    eviction pressure, so rounds mix fast commits with solo fallbacks."""
    _, _, _, _, prompts, refs = ms
    picks = [0, 2]                         # prompt lengths 4 and 9
    with _engine(ms, decode=decode, offload=offload, slots=8,
                 max_draft_len=5) as eng:
        res = eng.serve_all(_reqs([prompts[i] for i in picks]),
                            concurrency=2)
    for r, i in zip(res, picks):
        assert r.tokens == refs[i], (decode, offload)
        assert r.finish_reason == "length"
        assert r.metrics.tokens == TOK


def test_batched_rounds_ragged_adaptive_lengths(ms):
    """sd-adaptive diverges the sessions' draft lengths, so fused rounds see
    ragged [1, T_i] blocks; three sessions stay bit-identical to solo and
    the fused path really engaged (it traced)."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms, decode="sd-adaptive", offload="spmoe",
                 min_draft_len=1, max_draft_len=5) as eng:
        rt = eng.runtime
        res = eng.serve_all(_reqs(prompts), concurrency=3)
        assert rt._batched_traces > 0, "fused cross-session path never ran"
    for r, ref in zip(res, refs):
        assert r.tokens == ref
        assert r.finish_reason == "length"


# ---------------------------------------------------------------------------
# per-session miss fallback: one session falls back alone, batchmates commit
# ---------------------------------------------------------------------------

def test_missing_session_falls_back_alone(ms):
    """Force the fused all-hit flag False for session 1 on every round: that
    session must re-verify on the slow path (still lossless) while session 0
    keeps committing fused fast blocks with zero fallbacks."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as eng:
        rt = eng.runtime
        eng.serve_all(_reqs(prompts[:2]), concurrency=2)   # warm + arm
        forced = []
        orig = rt._verify_fast_batched

        def force_miss(*args):
            logits, ok, tcs, hists, nact = orig(*args)
            if ok.shape[0] >= 2:
                ok = ok.at[1].set(False)
                forced.append(1)
            return logits, ok, tcs, hists, nact

        rt._verify_fast_batched = force_miss
        res = eng.serve_all(_reqs(prompts[:2]), concurrency=2)
        rt._verify_fast_batched = orig
    assert forced, "no fused round ran on the warm engine"
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    assert res[1].metrics.fast_fallbacks >= 1
    assert res[0].metrics.fast_fallbacks == 0
    assert res[0].metrics.fast_blocks >= 1


# ---------------------------------------------------------------------------
# sync contract: ≤2 host syncs per ROUND (not per session)
# ---------------------------------------------------------------------------

def test_round_sync_contract_two_syncs_per_round(ms):
    """On the warm all-hit path a fused round serving two sessions performs
    at most 2 host syncs TOTAL (the per-session all-hit vector and the
    accept argmax, one readback each) — the solo contract was 2 per block,
    i.e. 2·N per round.  At least one round must commit both sessions'
    blocks inside that budget."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as eng:
        rt = eng.runtime
        eng.serve_all(_reqs(prompts[:2]), concurrency=2)   # warm + arm
        per_round = []
        orig = rt.session_turns

        def spy(sts):
            s0, b0 = rt.host_syncs, rt.fast_blocks
            out = orig(sts)
            per_round.append((rt.host_syncs - s0, rt.fast_blocks - b0))
            return out

        rt.session_turns = spy
        res = eng.serve_all(_reqs(prompts[:2]), concurrency=2)
        rt.session_turns = orig
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    verifying = [(s, b) for s, b in per_round if b > 0]
    assert verifying, "no round committed a fast block"
    assert max(s for s, _ in verifying) <= 2, \
        f"a round exceeded 2 host syncs: {per_round}"
    assert any(b == 2 for s, b in verifying if s <= 2), \
        f"no round committed both sessions within 2 syncs: {per_round}"


def test_fused_trace_shared_across_length_permutations(ms):
    """Ragged rounds are canonicalized by block length before the fused
    dispatch, so a (2,4) round and its (4,2) permutation reuse ONE compiled
    executable — sd-adaptive's drifting per-session lengths must not
    retrace per ordering (the analogue of the table-scatter bucket fix)."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as eng:
        rt = eng.runtime
        eng.serve_all(_reqs(prompts[:2]), concurrency=2)   # warm + arm
        st1 = rt.start_session(prompts[0], 8)
        st2 = rt.start_session(prompts[1], 8)
        rt.session_turns([st1, st2])       # deliver the prefill chunks
        t0 = rt._batched_traces
        st1.n, st2.n = 2, 4                # ragged round ...
        rt.session_turns([st1, st2])
        st1.n, st2.n = 4, 2                # ... and its permutation
        rt.session_turns([st1, st2])
        assert rt._batched_traces - t0 == 1, \
            "permuted block lengths recompiled the fused round"
        rt.finish_session(st1)
        rt.finish_session(st2)


def test_one_fused_launch_per_round_on_all_hit_path(ms):
    """Warm, ample cache: every verifying round dispatches exactly one
    fused verify launch (was one per session) and falls back never."""
    _, _, _, _, prompts, refs = ms
    with _engine(ms) as eng:
        rt = eng.runtime
        eng.serve_all(_reqs(prompts[:2]), concurrency=2)   # warm + arm
        r0, l0, f0 = rt.verify_rounds, rt.round_launches, rt.fast_fallbacks
        res = eng.serve_all(_reqs(prompts[:2]), concurrency=2)
        rounds = rt.verify_rounds - r0
        launches = rt.round_launches - l0
        assert rt.fast_fallbacks == f0, "warm all-hit serve fell back"
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    assert rounds > 0
    assert launches == rounds, \
        f"{launches} verify launches over {rounds} rounds (want 1/round)"


# ---------------------------------------------------------------------------
# property sweep: random decode x offload x schedule stays bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(decode=hs.sampled_from(DECODE_POLICIES),
       offload=hs.sampled_from([o for o in OFFLOAD_POLICIES if o != "none"]),
       tight=hs.booleans(),
       nreq=hs.integers(2, 3),
       tok=hs.integers(4, TOK))
def test_property_batched_rounds_bit_identical(decode, offload, tight, nreq,
                                               tok):
    """Randomly drawn decode x offload x cache-pressure x round-size x
    budget: every session's stream is bit-identical to its solo greedy
    reference, and per-request token budgets are honoured exactly."""
    ms = _ms()
    cfg, _, _, _, prompts, refs = ms
    slots = 8 if tight else cfg.num_moe_layers * cfg.num_experts
    with _engine(ms, decode=decode, offload=offload, slots=slots,
                 max_draft_len=5) as eng:
        res = eng.serve_all(_reqs(prompts[:nreq], n=tok), concurrency=nreq)
    for r, ref in zip(res, refs):
        assert r.tokens == ref[:tok], (decode, offload, tight, nreq, tok)
        assert r.metrics.tokens == tok
