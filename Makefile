# Entry points the CI workflow and humans share.  PYTHONPATH=src is the
# repo convention (no package install step; the container already has jax).

.PHONY: test test-fast test-engine test-serving test-chaos bench-offload bench-sessions bench-chaos

test:            ## tier-1 verify: the FULL suite (~13 min on the container)
	PYTHONPATH=src python -m pytest -x -q

test-fast:       ## CI tier: skips slow kernel sweeps + soaks (~8 min)
	PYTHONPATH=src python -m pytest -x -q -m "not slow"

test-engine:     ## pure serving-API signal (~3 min)
	PYTHONPATH=src python -m pytest -x -q tests/test_engine.py tests/test_sessions.py

test-serving:    ## full serving surface: engine + sessions + batched rounds
	PYTHONPATH=src python -m pytest -x -q tests/test_engine.py tests/test_sessions.py tests/test_batched_verify.py

test-chaos:      ## resilience: fault-injected serving + supervised prefetch (~2 min)
	PYTHONPATH=src python -m pytest -x -q tests/test_chaos.py

bench-offload:   ## verification hot-path micro-bench -> BENCH_offload.json
	PYTHONPATH=src python -m benchmarks.run --mode offload

bench-sessions:  ## serial vs concurrent sessions -> BENCH_sessions.json
	PYTHONPATH=src python -m benchmarks.run --mode sessions

bench-chaos:     ## fault-rate degradation curve + lossless gate -> BENCH_chaos.json
	PYTHONPATH=src python -m benchmarks.run --mode chaos
