"""Quickstart: SP-MoE serving a (reduced) Mixtral with speculative decoding
and drafting-stage expert prefetching — the paper's full pipeline, end to
end, on whatever device JAX has.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.runtime import OffloadEngine
from repro.core.sd import greedy_generate
from repro.models.registry import build_model


def main():
    # reduced Mixtral-8x7B (same family: 8 experts, top-2, SWA) in f32
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    draft_cfg = dataclasses.replace(
        cfg, num_experts=0, num_experts_per_tok=0, name="mistral-draft")
    print(f"target: {cfg.name}  ({cfg.num_layers}L, {cfg.num_experts} experts, "
          f"top-{cfg.num_experts_per_tok})")

    target = build_model(cfg)
    draft = build_model(draft_cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = draft.init(jax.random.PRNGKey(1))

    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)

    # reference: plain target-only greedy decoding
    t0 = time.perf_counter()
    ref = greedy_generate(target, tparams, prompt, 24, 64)
    print(f"\ngreedy reference ({time.perf_counter()-t0:.1f}s): {ref.tolist()}")

    # SP-MoE: experts offloaded to host, drafting-stage prefetch, LRU cache
    eng = OffloadEngine(cfg, draft_cfg, tparams, dparams, cache_slots=8,
                        draft_len=4, policy="spmoe", max_seq=64)
    t0 = time.perf_counter()
    out, stats = eng.generate(prompt, 24)
    eng.close()
    print(f"SP-MoE output     ({time.perf_counter()-t0:.1f}s): {out.tolist()}")
    print(f"\nlossless: {out.tolist() == ref.tolist()}")
    for k in ("hit_rate", "prefetched", "on_demand_loads", "acceptance_rate",
              "cutoff_layer", "evictions"):
        print(f"  {k}: {stats[k]}")


if __name__ == "__main__":
    main()
