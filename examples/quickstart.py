"""Quickstart: SP-MoE serving a (reduced) Mixtral with speculative decoding
and drafting-stage expert prefetching — the paper's full pipeline, end to
end, on whatever device JAX has, through the unified request API.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.configs.registry import get_config
from repro.core.engine import Engine, EngineConfig, Request
from repro.core.sd import greedy_generate
from repro.models.registry import build_model


def main():
    # reduced Mixtral-8x7B (same family: 8 experts, top-2, SWA) in f32
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    print(f"target: {cfg.name}  ({cfg.num_layers}L, {cfg.num_experts} experts, "
          f"top-{cfg.num_experts_per_tok})")

    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                cfg.vocab_size)

    # reference: plain target-only greedy decoding
    t0 = time.perf_counter()
    ref = greedy_generate(target, tparams, prompt, 24, 64)
    print(f"\ngreedy reference ({time.perf_counter()-t0:.1f}s): {ref.tolist()}")

    # SP-MoE: decode axis = speculative decoding, offload axis = drafting-
    # stage prefetch into a fixed-slot LRU expert cache
    config = EngineConfig(model=cfg, decode="sd", offload="spmoe",
                          cache_slots=8, draft_len=4, max_seq=64)
    with Engine(config, tparams) as eng:
        # stream the first request token-by-token (per committed verify block)
        t0 = time.perf_counter()
        print("SP-MoE stream:    ", end="", flush=True)
        for tok in eng.stream(Request(prompt=prompt, max_new_tokens=24)):
            print(tok, end=" ", flush=True)
        res = eng.last_result
        print(f" ({time.perf_counter()-t0:.1f}s)")
        print(f"\nlossless: {res.tokens == ref.tolist()}")
        for k in ("hit_rate", "prefetched", "on_demand_loads",
                  "acceptance_rate", "cutoff_layer", "evictions"):
            print(f"  {k}: {res.metrics[k]}")
        # request 2 reuses the warm expert cache — hit rate climbs
        res2 = eng.submit(Request(prompt=prompt, max_new_tokens=24))
        print(f"request 2 (warm cache) hit_rate: {res2.metrics.hit_rate:.2%} "
              f"(request 1: {res.metrics.hit_rate:.2%})")

        # two sessions decoded concurrently on the same warm cache: each
        # scheduling round gathers the ready sessions' draft blocks into ONE
        # fused verify dispatch (one routing pass, one cache_moe launch, ≤2
        # host syncs per round instead of 2 per session), and each stream
        # stays bit-identical to serving it alone
        prompt2 = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                     cfg.vocab_size)
        batch = eng.serve_all([Request(prompt=prompt, max_new_tokens=24),
                               Request(prompt=prompt2, max_new_tokens=24)],
                              concurrency=2)
        print(f"concurrent sessions lossless: "
              f"{batch[0].tokens == ref.tolist()} | per-request hit_rate: "
              f"{[f'{r.metrics.hit_rate:.2%}' for r in batch]}")

    # chaos drill: the same engine config under seeded fault injection —
    # transient I/O errors, payload corruption (checksum-quarantined) and
    # prefetch-worker kills.  Retries + the supervised worker + the
    # graceful-degradation ladder absorb every injected fault; the stream
    # stays bit-identical, only slower.  (CLI: repro.launch.serve --chaos;
    # counters: prefetch_errors/retries/checksum_failures/worker_restarts/
    # degraded_rounds/io_errors.)
    from repro.core.chaos import ChaosConfig
    chaos_cfg = EngineConfig(model=cfg, decode="sd", offload="spmoe",
                             cache_slots=8, draft_len=4, max_seq=64,
                             chaos=ChaosConfig(seed=7, fetch_error_rate=0.2,
                                               corrupt_rate=0.1,
                                               kill_worker_every=5))
    with Engine(chaos_cfg, tparams) as eng:
        res = eng.submit(Request(prompt=prompt, max_new_tokens=24))
        c = eng.runtime.counters()
        print(f"chaos drill lossless: {res.tokens == ref.tolist()} "
              f"(retries={c['prefetch_retries']} "
              f"checksum_failures={c['checksum_failures']} "
              f"worker_restarts={c['worker_restarts']} "
              f"health={eng.runtime.health()})")


if __name__ == "__main__":
    main()
