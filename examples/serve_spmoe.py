"""End-to-end serving driver: compares the four offloading policies (the
paper's frameworks) on the same reduced MoE model + prompt set, reporting
hit rate / prefetch / eviction stats per policy and validating that every
policy emits the identical (lossless) token stream.

    PYTHONPATH=src python examples/serve_spmoe.py [--arch deepseek-v2-lite-16b]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.runtime import POLICIES, OffloadEngine
from repro.core.sd import greedy_generate
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=20)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--cache-slots", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(dtype="float32")
    assert cfg.is_moe, "pick an MoE arch"
    dcfg = dataclasses.replace(cfg, num_experts=0, num_experts_per_tok=0,
                               num_shared_experts=0, first_dense_layers=0,
                               name="draft")
    target = build_model(cfg)
    draft = build_model(dcfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = draft.init(jax.random.PRNGKey(1))

    prompts = [jax.random.randint(jax.random.PRNGKey(10 + i), (1, 8), 0,
                                  cfg.vocab_size)
               for i in range(args.requests)]
    refs = [greedy_generate(target, tparams, p, args.tokens, 64).tolist()
            for p in prompts]

    print(f"{'policy':14s} {'lossless':9s} {'hit_rate':9s} {'prefetched':11s} "
          f"{'on_demand':10s} {'evict':6s} {'wall_s':7s}")
    for policy in POLICIES:
        eng = OffloadEngine(cfg, dcfg, tparams, dparams,
                            cache_slots=args.cache_slots, draft_len=4,
                            policy=policy, max_seq=64)
        ok, hit, pf, od, ev, wall = True, 0.0, 0, 0, 0, 0.0
        for p, ref in zip(prompts, refs):
            out, stats = eng.generate(p, args.tokens)
            ok &= out.tolist() == ref
            hit = stats["hit_rate"]
            pf += stats["prefetched"]
            od = stats["on_demand_loads"]
            ev = stats["evictions"]
            wall += stats["wall_s"]
        eng.close()
        print(f"{policy:14s} {str(ok):9s} {hit:9.2%} {pf:<11d} {od:<10d} "
              f"{ev:<6d} {wall:7.1f}")


if __name__ == "__main__":
    main()
