"""End-to-end serving driver: compares the four offloading policies (the
paper's frameworks) on the same reduced MoE model + prompt set through the
unified request API (one Engine per policy serving all requests against a
warm expert cache), reporting per-policy hit rate / prefetch / eviction
stats and validating that every policy emits the identical (lossless)
token stream.  With ``--concurrency > 1`` the requests are decoded
concurrently — the round-robin session scheduler interleaves one verify
block per session per turn on the shared cache, and the losslessness
column must stay True.

    PYTHONPATH=src python examples/serve_spmoe.py [--arch deepseek-v2-lite-16b]
    PYTHONPATH=src python examples/serve_spmoe.py --requests 3 --concurrency 3
"""
import argparse

import jax

from repro.configs.registry import get_config
from repro.core.engine import (Engine, EngineConfig, OffloadPolicy, Request,
                               derive_draft_config)
from repro.core.sd import greedy_generate
from repro.models.registry import build_model

OFFLOAD_POLICIES = [p.value for p in OffloadPolicy if p != OffloadPolicy.NONE]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=20)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--cache-slots", type=int, default=8)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="sessions decoded concurrently per engine "
                         "(round-robin on the shared cache; 1 = serial)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(dtype="float32")
    assert cfg.is_moe, "pick an MoE arch"
    dcfg = derive_draft_config(cfg)
    target = build_model(cfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = build_model(dcfg).init(jax.random.PRNGKey(1))

    prompts = [jax.random.randint(jax.random.PRNGKey(10 + i), (1, 8), 0,
                                  cfg.vocab_size)
               for i in range(args.requests)]
    refs = [greedy_generate(target, tparams, p, args.tokens, 64).tolist()
            for p in prompts]

    print(f"{'policy':14s} {'lossless':9s} {'hit_rate':9s} {'prefetched':11s} "
          f"{'on_demand':10s} {'evict':6s} {'wall_s':7s}")
    for policy in OFFLOAD_POLICIES:
        config = EngineConfig(model=cfg, draft=dcfg, decode="sd",
                              offload=policy, cache_slots=args.cache_slots,
                              draft_len=4, max_seq=64)
        with Engine(config, tparams, dparams) as eng:
            reqs = [Request(prompt=p, max_new_tokens=args.tokens)
                    for p in prompts]
            if args.concurrency > 1:
                results = eng.serve_all(reqs, concurrency=args.concurrency)
            else:
                results = [eng.submit(r) for r in reqs]
            ok = all(res.tokens == ref for res, ref in zip(results, refs))
            m = eng.metrics()    # cumulative across the request stream
        print(f"{policy:14s} {str(ok):9s} {m.hit_rate:9.2%} "
              f"{m.prefetched:<11d} {m.on_demand_loads:<10d} "
              f"{m.evictions:<6d} {m.wall_s:7.1f}")


if __name__ == "__main__":
    main()
