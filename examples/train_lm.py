"""Train a small MoE LM for a few hundred steps with the full substrate:
synthetic data pipeline, AdamW + cosine schedule, per-layer remat, async
checkpointing, and a mid-run simulated failure + restart (the fault-
tolerance path).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--big]

``--big`` uses a ~100M-param config (slow on CPU: ~seconds/step).
"""
import argparse
import tempfile
import time

from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.launch.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of the tiny default")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step to demo restart")
    args = ap.parse_args()

    if args.big:   # ~100M: 8L x 512d x 8 experts(256 ffn) top-2
        cfg = get_config("mixtral-8x7b").reduced(
            num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
            head_dim=64, moe_d_ff=1024, d_ff=1024, vocab_size=32000,
            num_experts=8, num_experts_per_tok=2, sliding_window=None)
        shape = ShapeConfig("ex", 256, 8, "train")
    else:
        cfg = get_config("mixtral-8x7b").reduced(sliding_window=None)
        shape = ShapeConfig("ex", 64, 8, "train")
    from repro.models.costmodel import count_params
    total, active = count_params(cfg)
    print(f"model: {total/1e6:.1f}M params ({active/1e6:.1f}M active/token)")

    run = RunConfig(microbatch=2, learning_rate=1e-3, warmup_steps=20,
                    total_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(cfg, shape, run, ckpt_dir=ckpt_dir)
        t0 = time.time()
        fail_at = args.fail_at if args.fail_at else args.steps // 2
        try:
            tr.train(args.steps, ckpt_every=25, fail_at=fail_at, log_every=20)
        except RuntimeError as e:
            print(f"!! {e} — restarting from checkpoint "
                  f"step {tr.ckpt.latest_step()}")
            tr2 = Trainer(cfg, shape, run, ckpt_dir=ckpt_dir)
            _, losses = tr2.train(args.steps, ckpt_every=25, log_every=20)
            print(f"recovered; final loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
