"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall-clock of the
measured operation; derived = the figure's headline metric).  The TPOT
numbers come from the calibrated event-driven simulator (core/simulator.py,
see DESIGN.md §2 — this container has no GPU/PCIe); hit rates are
additionally cross-checked against the REAL OffloadEngine on a reduced
config in ``engine_real``.

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run fig9 table3  # subset
    PYTHONPATH=src python -m benchmarks.run --mode offload [--out F.json]
                                          # real-engine offload micro-bench ->
                                          # BENCH_offload.json (perf tracking)
    PYTHONPATH=src python -m benchmarks.run --mode sessions [--out F.json]
                                          # multi-session serial vs concurrent
                                          # throughput -> BENCH_sessions.json
    PYTHONPATH=src python -m benchmarks.run --mode chaos [--out F.json]
                                          # fault-injected serving: throughput
                                          # degradation curve vs fault rate,
                                          # lossless gate -> BENCH_chaos.json
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core.simulator import (DATASETS, ENVS, SIM_MODELS, SimConfig,
                                  Simulator, simulate)

SEEDS = (0, 1, 2)
POLICIES = ("on-demand", "moe-infinity", "adapmoe", "spmoe")
POLICY_LABEL = {"on-demand": "MO", "moe-infinity": "MI", "adapmoe": "AdapMoE",
                "spmoe": "SP-MoE"}


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def fig9_datasets():
    """Figure 9: TPOT across four datasets (mixtral, three envs)."""
    for env in ("3090", "4090", "a100"):
        for ds in DATASETS:
            base = None
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS["mixtral-8x7b"], ENVS[env],
                                SimConfig(policy=pol, dataset=ds, seed=s,
                                          out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                tpot = float(np.mean([r.tpot for r in rs]))
                if base is None:
                    base = tpot
                _row(f"fig9.{env}.{ds}.{POLICY_LABEL[pol]}", wall,
                     f"tpot_ms={tpot*1e3:.1f};speedup_vs_MO={base/tpot:.2f}")


def fig10_models():
    """Figure 10: TPOT across the three model pairs and three envs."""
    for model in SIM_MODELS:
        for env in ("3090", "4090", "a100"):
            base = None
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS[model], ENVS[env],
                                SimConfig(policy=pol, seed=s, out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                tpot = float(np.mean([r.tpot for r in rs]))
                if base is None:
                    base = tpot
                _row(f"fig10.{model}.{env}.{POLICY_LABEL[pol]}", wall,
                     f"tpot_ms={tpot*1e3:.1f};speedup_vs_MO={base/tpot:.2f}")


def table3_hit_rate():
    """Table 3: hit rates across datasets/models/frameworks."""
    for model in SIM_MODELS:
        for ds in DATASETS:
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS[model], ENVS["4090"],
                                SimConfig(policy=pol, dataset=ds, seed=s,
                                          out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                hit = float(np.mean([r.hit_rate for r in rs]))
                _row(f"table3.{model}.{ds}.{POLICY_LABEL[pol]}", wall,
                     f"hit_rate={hit:.3f}")


def fig11_memory():
    """Figure 11: TPOT vs GPU memory (deepseek pair, HumanEval, env3)."""
    for mem in (7, 12, 16, 24, 32, 39):
        for pol in POLICIES:
            t0 = time.perf_counter()
            rs = [Simulator(SIM_MODELS["deepseek-v2-lite-16b"], ENVS["a100"],
                            SimConfig(policy=pol, gpu_mem_gb=float(mem),
                                      seed=s, out_tokens=100)).run()
                  for s in SEEDS]
            wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
            tpot = float(np.mean([r.tpot for r in rs]))
            _row(f"fig11.mem{mem}GB.{POLICY_LABEL[pol]}", wall,
                 f"tpot_ms={tpot*1e3:.1f}")


def fig12_ablation():
    """Figure 12: baseline -> +vanilla prefetch -> +worker -> +batched IO."""
    for model in SIM_MODELS:
        t0 = time.perf_counter()
        variants = {
            "baseline": dict(policy="on-demand"),
            "vp": dict(policy="spmoe", worker_prefetch=False, batched_io=False),
            "wp": dict(policy="spmoe", worker_prefetch=True, batched_io=False),
            "wp+b": dict(policy="spmoe", worker_prefetch=True, batched_io=True),
        }
        base = None
        for name, kw in variants.items():
            tpot = float(np.mean([simulate(model, seed=s, out_tokens=100,
                                           **kw).tpot for s in SEEDS]))
            if base is None:
                base = tpot
            wall = (time.perf_counter() - t0) * 1e6
            _row(f"fig12.{model}.{name}", wall,
                 f"tpot_ms={tpot*1e3:.1f};speedup={base/tpot:.2f}")


def fig13_draft_len():
    """Figure 13: TPOT vs draft token length across envs (mixtral)."""
    for env in ("3090", "4090", "a100"):
        for n in (1, 2, 4, 6, 8):
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS["mixtral-8x7b"], ENVS[env],
                                SimConfig(policy=pol, draft_len=n, seed=s,
                                          out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                tpot = float(np.mean([r.tpot for r in rs]))
                _row(f"fig13.{env}.N{n}.{POLICY_LABEL[pol]}", wall,
                     f"tpot_ms={tpot*1e3:.1f}")


def fig14_cutoff():
    """Figure 14: TPOT vs cutoff layer (U-shape mixtral/phi, monotone ds)."""
    for model in SIM_MODELS:
        L = SIM_MODELS[model].num_layers
        for c in (0, 5, 10, 15, 20, 25, L - 1):
            c = min(c, L - 1)
            t0 = time.perf_counter()
            tpot = float(np.mean([simulate(model, policy="spmoe", cutoff=c,
                                           seed=s, out_tokens=100).tpot
                                  for s in SEEDS]))
            wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
            _row(f"fig14.{model}.cutoff{c}", wall, f"tpot_ms={tpot*1e3:.1f}")


def fig2_observations():
    """Figure 2: activation overlap of neighbouring tokens + prediction-
    strategy entropies."""
    from repro.core.predictor import strategy_entropies
    for model in SIM_MODELS:
        sim = Simulator(SIM_MODELS[model], ENVS["4090"], SimConfig(seed=0))
        t0 = time.perf_counter()
        overlaps = []
        for _ in range(200):
            blk = sim._sample_tokens(0, 2)
            a, b = set(blk[0].tolist()), set(blk[1].tolist())
            overlaps.append(len(a & b) / len(a | b))
        wall = (time.perf_counter() - t0) * 1e6
        _row(f"fig2b.{model}.overlap", wall,
             f"mean_jaccard={float(np.mean(overlaps)):.3f}")
        E = SIM_MODELS[model].num_experts
        probs = np.exp(np.random.default_rng(0).normal(size=(256, E)) * 2.5)
        probs /= probs.sum(-1, keepdims=True)
        ent = strategy_entropies(probs, sim.history[0] + 1)
        _row(f"fig2c.{model}.entropy", wall,
             f"random={ent['random']:.2f};coarse={ent['coarse_grained']:.2f};"
             f"gating={ent['gating_based']:.2f}")


def fig4_latency_split():
    """Figure 4: decode-iteration latency distribution (loading dominates)."""
    for model in SIM_MODELS:
        t0 = time.perf_counter()
        r = simulate(model, policy="on-demand", seed=0, out_tokens=100)
        wall = (time.perf_counter() - t0) * 1e6
        tot = r.io_time + r.compute_time + r.draft_time
        _row(f"fig4.{model}", wall,
             f"loading={r.io_time/tot:.2f};draft={r.draft_time/tot:.2f};"
             f"compute={r.compute_time/tot:.2f}")


def engine_real():
    """Cross-check: REAL serving engine (reduced mixtral, CPU) — SP-MoE's
    hit rate must beat on-demand's, as in the simulator.  Goes through the
    unified request API (core/engine.py)."""
    import jax
    from repro.configs.registry import get_config
    from repro.core.engine import Engine, EngineConfig, Request

    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    tparams = None
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    hits = {}
    for pol in ("on-demand", "spmoe"):
        config = EngineConfig(model=cfg, decode="sd", offload=pol,
                              cache_slots=8, draft_len=4, max_seq=64)
        with Engine(config, tparams) as eng:
            tparams = eng.tparams          # share the init across engines
            t0 = time.perf_counter()
            res = eng.submit(Request(prompt=prompt, max_new_tokens=16))
            wall = (time.perf_counter() - t0) * 1e6
        m = res.metrics
        hits[pol] = m.hit_rate
        _row(f"engine_real.mixtral-reduced.{POLICY_LABEL[pol]}", wall,
             f"hit_rate={m.hit_rate:.3f};prefetched={m.prefetched}")
    assert hits["spmoe"] >= hits["on-demand"]


def offload_micro(out_path: str = "BENCH_offload.json"):
    """Real serving-engine micro-benchmark: TPOT / hit rate / on-demand
    loads / host-sync count, spmoe vs on-demand, written to ``out_path`` so
    the perf trajectory of the verification hot path is tracked PR over PR.

    Goes through the unified request API: one Engine per (setting, policy)
    serves a warmup request (compiles fast+slow verify paths — the fast
    path is additionally pre-traced at engine init) followed by 3 measured
    requests; Metrics snapshots are per-request deltas, so no stat reset is
    needed between runs and the best-of-3 reflects steady-state decode.
    """
    import jax
    from repro.configs.registry import get_config
    from repro.core.engine import Engine, EngineConfig, Request

    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    tparams = dparams = None
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                cfg.vocab_size)
    n_tokens = 24
    total_experts = cfg.num_moe_layers * cfg.num_experts
    settings = {
        "tight": 2 * cfg.num_experts,    # I/O-bound: constant miss pressure
        "ample": total_experts,          # hot-path-bound: fast path engages
    }
    results = {}
    for setting, slots in settings.items():
        for pol in ("spmoe", "on-demand"):
            config = EngineConfig(model=cfg, decode="sd", offload=pol,
                                  cache_slots=slots, draft_len=4, max_seq=96)
            with Engine(config, tparams, dparams) as eng:
                tparams, dparams = eng.tparams, eng.dparams  # share init
                eng.submit(Request(prompt=prompt, max_new_tokens=n_tokens))
                best = None
                for _ in range(3):           # best-of-3: CPU wall clocks are
                    t0 = time.perf_counter()  # noisy; min is noise-robust
                    res = eng.submit(Request(prompt=prompt,
                                             max_new_tokens=n_tokens))
                    wall = (time.perf_counter() - t0) * 1e6
                    if best is None or res.metrics.tpot_wall < best[0].tpot_wall:
                        best = (res.metrics, wall)
            m, wall = best
            results[f"{setting}.{pol}"] = {
                "cache_slots": slots,
                "tpot_s": m.tpot_wall,
                "hit_rate": m.hit_rate,
                "on_demand_loads": m.on_demand_loads,
                "host_syncs": m.host_syncs,
                "verify_blocks": m.verify_blocks,
                "fast_blocks": m.fast_blocks,
                "fast_fallbacks": m.fast_fallbacks,
                "prefetched": m.prefetched,
                "acceptance_rate": m.acceptance_rate,
            }
            _row(f"offload.{setting}.{POLICY_LABEL[pol]}", wall,
                 f"tpot_ms={m.tpot_wall*1e3:.2f};"
                 f"hit_rate={m.hit_rate:.3f};"
                 f"host_syncs={m.host_syncs};"
                 f"fast_blocks={m.fast_blocks}")
    results["meta"] = {
        "model": "mixtral-8x7b.reduced", "draft_len": 4,
        "n_tokens": n_tokens,
        "speedup_spmoe_vs_on_demand_tight":
            results["tight.on-demand"]["tpot_s"]
            / max(results["tight.spmoe"]["tpot_s"], 1e-12),
        "syncs_per_block_ample_spmoe":
            results["ample.spmoe"]["host_syncs"]
            / max(results["ample.spmoe"]["verify_blocks"], 1),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}", file=sys.stderr)


def sessions_micro(out_path: str = "BENCH_sessions.json"):
    """Multi-session serving micro-benchmark: the same request batch decoded
    serially (submit one after another) vs concurrently (Engine.serve
    round-robin, one verify block per session per turn) on the SAME warm
    spmoe engine, written to ``out_path`` so the scheduler's throughput
    trajectory is tracked PR over PR.

    The concurrent schedule batches every round's ready verify blocks into
    ONE fused cross-session dispatch (still lossless — asserted below), so
    the structural metrics to track PR over PR are ``launches_per_round``
    (= 1 on the all-hit path; was one per session) and ``syncs_per_block``
    (2/N per fused round vs 2 serial), with
    ``throughput_ratio_concurrent_vs_serial`` >= its PR-4 value (1.13) now
    that each round pays one dispatch + 2 syncs instead of N dispatches +
    2·N syncs.  Best-of-5 for both schedules (min wall) keeps the CPU
    wall-clock noise out of the ratio.
    """
    import jax
    from repro.configs.registry import get_config
    from repro.core.engine import Engine, EngineConfig, Request

    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    n_tokens, n_requests, conc = 24, 2, 2
    slots = cfg.num_moe_layers * cfg.num_experts       # ample: fast path
    prompts = [jax.random.randint(jax.random.PRNGKey(2 + i), (1, 8), 0,
                                  cfg.vocab_size) for i in range(n_requests)]

    def reqs():
        return [Request(prompt=p, max_new_tokens=n_tokens,
                        request_id=f"req-{i}")
                for i, p in enumerate(prompts)]

    config = EngineConfig(model=cfg, decode="sd", offload="spmoe",
                          cache_slots=slots, draft_len=4, max_seq=96)
    results = {}
    with Engine(config) as eng:
        # warm: compiles fast+slow verify paths for both schedules' shapes
        # and fills the expert cache
        for r in reqs():
            eng.submit(r)
        eng.serve_all(reqs(), concurrency=conc)

        best = {}
        for _ in range(5):           # best-of-5: the two schedules run the
            # identical per-session device work, so more trials converge the
            # ratio to its structural value instead of CPU scheduling jitter
            t0 = time.perf_counter()
            serial = [eng.submit(r) for r in reqs()]
            w_serial = time.perf_counter() - t0
            rt = eng.runtime
            vr0, rl0 = rt.verify_rounds, rt.round_launches
            t0 = time.perf_counter()
            conc_res = eng.serve_all(reqs(), concurrency=conc)
            w_conc = time.perf_counter() - t0
            rounds = rt.verify_rounds - vr0
            launches = rt.round_launches - rl0
            # interleaving must be lossless vs the serial schedule
            assert [r.tokens for r in serial] == [r.tokens for r in conc_res]
            if "serial" not in best or w_serial < best["serial"][0]:
                best["serial"] = (w_serial, serial, None, None)
            if "concurrent" not in best or w_conc < best["concurrent"][0]:
                best["concurrent"] = (w_conc, conc_res, rounds, launches)

    for sched, (wall, rs, rounds, launches) in best.items():
        total_tokens = sum(len(r.tokens) for r in rs)
        syncs = sum(r.metrics.host_syncs for r in rs)
        blocks = sum(r.metrics.verify_blocks for r in rs)
        results[sched] = {
            "wall_s": wall,
            "throughput_tok_s": total_tokens / wall,
            "tpot_s_mean": float(np.mean([r.metrics.tpot_wall for r in rs])),
            "host_syncs": syncs,
            "verify_blocks": blocks,
            "syncs_per_block": syncs / max(blocks, 1),
            "fast_blocks": sum(r.metrics.fast_blocks for r in rs),
            "fast_fallbacks": sum(r.metrics.fast_fallbacks for r in rs),
        }
        if rounds is not None:       # batched-round accounting (concurrent
            results[sched].update({  # schedule only): 1 fused launch and
                "rounds": rounds,    # <=2 syncs per all-hit round
                "launches_per_round": launches / max(rounds, 1),
                "syncs_per_round": syncs / max(rounds, 1),
            })
        _row(f"sessions.{sched}", wall * 1e6,
             f"throughput_tok_s={results[sched]['throughput_tok_s']:.1f};"
             f"syncs_per_block={results[sched]['syncs_per_block']:.2f}")
    results["meta"] = {
        "model": "mixtral-8x7b.reduced", "draft_len": 4,
        "n_requests": n_requests, "n_tokens": n_tokens,
        "concurrency": conc, "cache_slots": slots,
        "lossless_vs_serial": True,        # asserted per trial above
        "throughput_ratio_concurrent_vs_serial":
            results["concurrent"]["throughput_tok_s"]
            / max(results["serial"]["throughput_tok_s"], 1e-12),
        "launches_per_round_concurrent":
            results["concurrent"]["launches_per_round"],
        "syncs_per_block_concurrent":
            results["concurrent"]["syncs_per_block"],
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}", file=sys.stderr)


def chaos_micro(out_path: str = "BENCH_chaos.json"):
    """Chaos-hardened serving micro-benchmark: throughput / TPOT degradation
    curve vs injected fault rate, written to ``out_path``.

    One spmoe engine per fault rate serves the SAME 8 requests at
    concurrency 8 under the seeded fault injector (core/chaos.py):
    transient fetch errors at the swept rate, staged-payload corruption,
    latency spikes, and periodic prefetch-worker kills at the nonzero rates
    (kill_worker_every=5 exhausts the restart budget mid-run, so the
    graceful-degradation ladder demonstrably engages — asserted via
    ``degraded_rounds > 0``).  Losslessness is the hard gate: every rate's
    token streams must be bit-identical to the fault-free baseline; the
    bench FAILS otherwise.  Resilience counters (retries, checksum
    quarantines, worker restarts, degraded rounds, io_errors) are recorded
    per rate so the degradation curve is auditable PR over PR.
    """
    import jax
    from repro.configs.registry import get_config
    from repro.core.chaos import ChaosConfig
    from repro.core.engine import Engine, EngineConfig, Request

    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    n_tokens, n_requests, conc = 16, 8, 8
    rates = (0.0, 0.05, 0.15, 0.30)
    slots = 2 * cfg.num_experts            # tight-ish: real I/O pressure
    prompts = [jax.random.randint(jax.random.PRNGKey(2 + i), (1, 8), 0,
                                  cfg.vocab_size) for i in range(n_requests)]

    def reqs():
        return [Request(prompt=p, max_new_tokens=n_tokens,
                        request_id=f"req-{i}")
                for i, p in enumerate(prompts)]

    tparams = dparams = None
    baseline_tokens = None
    results = {}
    for rate in rates:
        chaos = None
        if rate > 0:
            chaos = ChaosConfig(seed=7, fetch_error_rate=rate,
                                insert_error_rate=rate / 4,
                                corrupt_rate=rate / 2,
                                spike_rate=rate / 4, spike_s=0.002,
                                kill_worker_every=5)
        config = EngineConfig(model=cfg, decode="sd", offload="spmoe",
                              cache_slots=slots, draft_len=4, max_seq=96,
                              chaos=chaos, retry_backoff_s=0.001)
        with Engine(config, tparams, dparams) as eng:
            tparams, dparams = eng.tparams, eng.dparams    # share init
            eng.serve_all(reqs(), concurrency=conc)        # warm/compile
            t0 = time.perf_counter()
            res = eng.serve_all(reqs(), concurrency=conc)
            wall = time.perf_counter() - t0
            c = eng.runtime.counters()
            health = eng.runtime.health()
            injected = dict(eng.runtime.chaos.injected) \
                if eng.runtime.chaos is not None else {}
        tokens = [r.tokens for r in res]
        assert all(r.finish_reason == "length" for r in res), \
            [r.finish_reason for r in res]
        if baseline_tokens is None:
            baseline_tokens = tokens
        # the losslessness gate: injected faults may slow serving down,
        # they must NEVER change a committed token
        assert tokens == baseline_tokens, f"token drift at fault rate {rate}"
        if rate > 0:
            assert c["prefetch_retries"] > 0 or c["prefetch_errors"] > 0, c
            assert c["degraded_rounds"] > 0, c
        total_tokens = sum(len(t) for t in tokens)
        results[f"rate_{rate}"] = {
            "fault_rate": rate,
            "wall_s": wall,
            "throughput_tok_s": total_tokens / wall,
            "tpot_s_mean": float(np.mean([r.metrics.tpot_wall for r in res])),
            "prefetch_errors": c["prefetch_errors"],
            "prefetch_retries": c["prefetch_retries"],
            "checksum_failures": c["checksum_failures"],
            "worker_restarts": c["worker_restarts"],
            "degraded_rounds": c["degraded_rounds"],
            "io_errors": c["io_errors"],
            "health": health,
            "injected": injected,
        }
        _row(f"chaos.rate{rate}", wall * 1e6,
             f"throughput_tok_s={results[f'rate_{rate}']['throughput_tok_s']:.1f};"
             f"retries={c['prefetch_retries']};"
             f"degraded_rounds={c['degraded_rounds']};health={health}")
    base_tp = results["rate_0.0"]["throughput_tok_s"]
    results["meta"] = {
        "model": "mixtral-8x7b.reduced", "draft_len": 4,
        "n_requests": n_requests, "n_tokens": n_tokens,
        "concurrency": conc, "cache_slots": slots,
        "lossless_vs_fault_free": True,    # asserted per rate above
        "degradation_curve": {
            f"rate_{r}": results[f"rate_{r}"]["throughput_tok_s"] / base_tp
            for r in rates},
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}", file=sys.stderr)


def kernels_bench():
    """Pallas kernels, interpret-mode timing vs jnp oracle (CPU proxy —
    real perf comes from the §Roofline analysis)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref as R
    from repro.kernels.flash_attention import flash_attention

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 64), jnp.float32)
    for name, fn in (
        ("flash_interp", lambda: flash_attention(q, k, v, interpret=True)),
        ("jnp_ref", lambda: R.attention_ref(q, k, v)),
    ):
        fn()  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        wall = (time.perf_counter() - t0) * 1e6 / 3
        _row(f"kernels.attention_128.{name}", wall, "allclose=see tests")


BENCHES = {
    "fig2": fig2_observations,
    "fig4": fig4_latency_split,
    "fig9": fig9_datasets,
    "fig10": fig10_models,
    "fig11": fig11_memory,
    "fig12": fig12_ablation,
    "fig13": fig13_draft_len,
    "fig14": fig14_cutoff,
    "table3": table3_hit_rate,
    "engine_real": engine_real,
    "kernels": kernels_bench,
    "offload": offload_micro,
    "sessions": sessions_micro,
    "chaos": chaos_micro,
}

# benches that write a JSON artifact (support --out)
_OUT_DEFAULT = {"offload": "BENCH_offload.json",
                "sessions": "BENCH_sessions.json",
                "chaos": "BENCH_chaos.json"}


def main() -> None:
    argv = sys.argv[1:]
    out_path = None
    if "--out" in argv:
        i = argv.index("--out")
        out_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    if "--mode" in argv:                 # --mode X == positional X
        i = argv.index("--mode")
        argv = argv[:i] + [argv[i + 1]] + argv[i + 2:]
    which = argv or list(BENCHES)
    writers = [n for n in which if n in _OUT_DEFAULT]
    if out_path is not None and len(writers) != 1:
        sys.exit(f"--out covers exactly one artifact-writing bench, but the "
                 f"selection {which} includes {writers or 'none'}; pick one "
                 f"of --mode {'/'.join(_OUT_DEFAULT)}")
    print("name,us_per_call,derived")
    for name in which:
        if name in _OUT_DEFAULT:
            BENCHES[name](out_path or _OUT_DEFAULT[name])
        else:
            BENCHES[name]()


if __name__ == "__main__":
    main()
