"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall-clock of the
measured operation; derived = the figure's headline metric).  The TPOT
numbers come from the calibrated event-driven simulator (core/simulator.py,
see DESIGN.md §2 — this container has no GPU/PCIe); hit rates are
additionally cross-checked against the REAL OffloadEngine on a reduced
config in ``engine_real``.

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run fig9 table3  # subset
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.simulator import (DATASETS, ENVS, SIM_MODELS, SimConfig,
                                  Simulator, simulate)

SEEDS = (0, 1, 2)
POLICIES = ("on-demand", "moe-infinity", "adapmoe", "spmoe")
POLICY_LABEL = {"on-demand": "MO", "moe-infinity": "MI", "adapmoe": "AdapMoE",
                "spmoe": "SP-MoE"}


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def fig9_datasets():
    """Figure 9: TPOT across four datasets (mixtral, three envs)."""
    for env in ("3090", "4090", "a100"):
        for ds in DATASETS:
            base = None
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS["mixtral-8x7b"], ENVS[env],
                                SimConfig(policy=pol, dataset=ds, seed=s,
                                          out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                tpot = float(np.mean([r.tpot for r in rs]))
                if base is None:
                    base = tpot
                _row(f"fig9.{env}.{ds}.{POLICY_LABEL[pol]}", wall,
                     f"tpot_ms={tpot*1e3:.1f};speedup_vs_MO={base/tpot:.2f}")


def fig10_models():
    """Figure 10: TPOT across the three model pairs and three envs."""
    for model in SIM_MODELS:
        for env in ("3090", "4090", "a100"):
            base = None
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS[model], ENVS[env],
                                SimConfig(policy=pol, seed=s, out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                tpot = float(np.mean([r.tpot for r in rs]))
                if base is None:
                    base = tpot
                _row(f"fig10.{model}.{env}.{POLICY_LABEL[pol]}", wall,
                     f"tpot_ms={tpot*1e3:.1f};speedup_vs_MO={base/tpot:.2f}")


def table3_hit_rate():
    """Table 3: hit rates across datasets/models/frameworks."""
    for model in SIM_MODELS:
        for ds in DATASETS:
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS[model], ENVS["4090"],
                                SimConfig(policy=pol, dataset=ds, seed=s,
                                          out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                hit = float(np.mean([r.hit_rate for r in rs]))
                _row(f"table3.{model}.{ds}.{POLICY_LABEL[pol]}", wall,
                     f"hit_rate={hit:.3f}")


def fig11_memory():
    """Figure 11: TPOT vs GPU memory (deepseek pair, HumanEval, env3)."""
    for mem in (7, 12, 16, 24, 32, 39):
        for pol in POLICIES:
            t0 = time.perf_counter()
            rs = [Simulator(SIM_MODELS["deepseek-v2-lite-16b"], ENVS["a100"],
                            SimConfig(policy=pol, gpu_mem_gb=float(mem),
                                      seed=s, out_tokens=100)).run()
                  for s in SEEDS]
            wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
            tpot = float(np.mean([r.tpot for r in rs]))
            _row(f"fig11.mem{mem}GB.{POLICY_LABEL[pol]}", wall,
                 f"tpot_ms={tpot*1e3:.1f}")


def fig12_ablation():
    """Figure 12: baseline -> +vanilla prefetch -> +worker -> +batched IO."""
    for model in SIM_MODELS:
        t0 = time.perf_counter()
        variants = {
            "baseline": dict(policy="on-demand"),
            "vp": dict(policy="spmoe", worker_prefetch=False, batched_io=False),
            "wp": dict(policy="spmoe", worker_prefetch=True, batched_io=False),
            "wp+b": dict(policy="spmoe", worker_prefetch=True, batched_io=True),
        }
        base = None
        for name, kw in variants.items():
            tpot = float(np.mean([simulate(model, seed=s, out_tokens=100,
                                           **kw).tpot for s in SEEDS]))
            if base is None:
                base = tpot
            wall = (time.perf_counter() - t0) * 1e6
            _row(f"fig12.{model}.{name}", wall,
                 f"tpot_ms={tpot*1e3:.1f};speedup={base/tpot:.2f}")


def fig13_draft_len():
    """Figure 13: TPOT vs draft token length across envs (mixtral)."""
    for env in ("3090", "4090", "a100"):
        for n in (1, 2, 4, 6, 8):
            for pol in POLICIES:
                t0 = time.perf_counter()
                rs = [Simulator(SIM_MODELS["mixtral-8x7b"], ENVS[env],
                                SimConfig(policy=pol, draft_len=n, seed=s,
                                          out_tokens=100)).run()
                      for s in SEEDS]
                wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
                tpot = float(np.mean([r.tpot for r in rs]))
                _row(f"fig13.{env}.N{n}.{POLICY_LABEL[pol]}", wall,
                     f"tpot_ms={tpot*1e3:.1f}")


def fig14_cutoff():
    """Figure 14: TPOT vs cutoff layer (U-shape mixtral/phi, monotone ds)."""
    for model in SIM_MODELS:
        L = SIM_MODELS[model].num_layers
        for c in (0, 5, 10, 15, 20, 25, L - 1):
            c = min(c, L - 1)
            t0 = time.perf_counter()
            tpot = float(np.mean([simulate(model, policy="spmoe", cutoff=c,
                                           seed=s, out_tokens=100).tpot
                                  for s in SEEDS]))
            wall = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
            _row(f"fig14.{model}.cutoff{c}", wall, f"tpot_ms={tpot*1e3:.1f}")


def fig2_observations():
    """Figure 2: activation overlap of neighbouring tokens + prediction-
    strategy entropies."""
    from repro.core.predictor import strategy_entropies
    for model in SIM_MODELS:
        sim = Simulator(SIM_MODELS[model], ENVS["4090"], SimConfig(seed=0))
        t0 = time.perf_counter()
        overlaps = []
        for _ in range(200):
            blk = sim._sample_tokens(0, 2)
            a, b = set(blk[0].tolist()), set(blk[1].tolist())
            overlaps.append(len(a & b) / len(a | b))
        wall = (time.perf_counter() - t0) * 1e6
        _row(f"fig2b.{model}.overlap", wall,
             f"mean_jaccard={float(np.mean(overlaps)):.3f}")
        E = SIM_MODELS[model].num_experts
        probs = np.exp(np.random.default_rng(0).normal(size=(256, E)) * 2.5)
        probs /= probs.sum(-1, keepdims=True)
        ent = strategy_entropies(probs, sim.history[0] + 1)
        _row(f"fig2c.{model}.entropy", wall,
             f"random={ent['random']:.2f};coarse={ent['coarse_grained']:.2f};"
             f"gating={ent['gating_based']:.2f}")


def fig4_latency_split():
    """Figure 4: decode-iteration latency distribution (loading dominates)."""
    for model in SIM_MODELS:
        t0 = time.perf_counter()
        r = simulate(model, policy="on-demand", seed=0, out_tokens=100)
        wall = (time.perf_counter() - t0) * 1e6
        tot = r.io_time + r.compute_time + r.draft_time
        _row(f"fig4.{model}", wall,
             f"loading={r.io_time/tot:.2f};draft={r.draft_time/tot:.2f};"
             f"compute={r.compute_time/tot:.2f}")


def engine_real():
    """Cross-check: REAL OffloadEngine (reduced mixtral, CPU) — SP-MoE's hit
    rate must beat on-demand's, as in the simulator."""
    import dataclasses
    import jax
    from repro.configs.registry import get_config
    from repro.core.runtime import OffloadEngine
    from repro.models.registry import build_model

    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    dcfg = dataclasses.replace(cfg, num_experts=0, num_experts_per_tok=0,
                               name="draft")
    target = build_model(cfg)
    draft = build_model(dcfg)
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = draft.init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    hits = {}
    for pol in ("on-demand", "spmoe"):
        eng = OffloadEngine(cfg, dcfg, tparams, dparams, cache_slots=8,
                            draft_len=4, policy=pol, max_seq=64)
        t0 = time.perf_counter()
        _, stats = eng.generate(prompt, 16)
        wall = (time.perf_counter() - t0) * 1e6
        eng.close()
        hits[pol] = stats["hit_rate"]
        _row(f"engine_real.mixtral-reduced.{POLICY_LABEL[pol]}", wall,
             f"hit_rate={stats['hit_rate']:.3f};prefetched={stats['prefetched']}")
    assert hits["spmoe"] >= hits["on-demand"]


def kernels_bench():
    """Pallas kernels, interpret-mode timing vs jnp oracle (CPU proxy —
    real perf comes from the §Roofline analysis)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref as R
    from repro.kernels.flash_attention import flash_attention

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 64), jnp.float32)
    for name, fn in (
        ("flash_interp", lambda: flash_attention(q, k, v, interpret=True)),
        ("jnp_ref", lambda: R.attention_ref(q, k, v)),
    ):
        fn()  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        wall = (time.perf_counter() - t0) * 1e6 / 3
        _row(f"kernels.attention_128.{name}", wall, "allclose=see tests")


BENCHES = {
    "fig2": fig2_observations,
    "fig4": fig4_latency_split,
    "fig9": fig9_datasets,
    "fig10": fig10_models,
    "fig11": fig11_memory,
    "fig12": fig12_ablation,
    "fig13": fig13_draft_len,
    "fig14": fig14_cutoff,
    "table3": table3_hit_rate,
    "engine_real": engine_real,
    "kernels": kernels_bench,
}


def main() -> None:
    which = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        BENCHES[name]()


if __name__ == "__main__":
    main()
