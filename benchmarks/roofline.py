"""Roofline report generator: reads dryrun_results.json and emits the
per-(arch × shape × mesh) three-term table for EXPERIMENTS.md §Roofline.

    PYTHONPATH=src python -m benchmarks.roofline dryrun_results.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def fmt_time(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t*1e3:.1f}ms"
    return f"{t*1e6:.0f}us"


def _recompute(r: Dict) -> Dict:
    """Recompute roofline terms live from the analytical model (keeps the
    report in sync with costmodel.py without re-lowering)."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.models.costmodel import BYTES, count_params, roofline_terms
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    mode = "train" if shape.kind == "train" else "serve"
    if "weight_gather" in r:
        wg = r["weight_gather"]
    else:
        # mirror sharding.param_pspecs' serve auto-decision
        total, _ = count_params(cfg)
        per_shard = total * BYTES[cfg.dtype] / r["mesh"].get("model", 1)
        wg = mode == "serve" and per_shard > 10e9
    return roofline_terms(cfg, shape, r["mesh"], mode, weight_gather=wg,
                          verify_block=r.get("verify_block", 1),
                          capacity_factor=r.get("capacity_factor"),
                          remat=r.get("remat_override"),
                          grad_compress=r.get("grad_compress", False))


def rows(results: List[Dict], mesh_filter=None) -> List[str]:
    out = []
    for r in results:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | SKIP | "
                       f"{r['reason'][:60]} | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | ERROR | "
                       f"{r.get('error','')[:60]} | | | | |")
            continue
        mesh = "x".join(str(v) for v in r["mesh"].values())
        if mesh_filter and mesh != mesh_filter:
            continue
        rf = r["roofline"] = _recompute(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {rf['dominant']} | "
            f"{fmt_time(rf['t_compute'])} | {fmt_time(rf['t_memory'])} | "
            f"{fmt_time(rf['t_collective'])} | {rf['useful_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.2f} |")
    return out


HEADER = ("| arch | shape | mesh | bottleneck | t_compute | t_memory | "
          "t_collective | useful FLOP ratio | roofline fraction |\n"
          "|---|---|---|---|---|---|---|---|---|")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print(HEADER)
    seen = set()
    for r in results:
        key = (r["arch"], r["shape"], json.dumps(r.get("mesh", {}), sort_keys=True))
        if key in seen:
            continue
        seen.add(key)
    for line in rows(results):
        print(line)
    ok = [r for r in results if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["t_collective"]
                   / max(max(r["roofline"]["t_compute"],
                             r["roofline"]["t_memory"],
                             r["roofline"]["t_collective"]), 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"x {worst['mesh']} ({worst['roofline']['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']} "
              f"x {coll['mesh']}")


if __name__ == "__main__":
    main()
