"""Cutoff-layer policy (paper §3.2).

Chooses the deepest layer L such that prefetching k experts for every layer
0..L during the drafting stage (a) fits GPU/HBM memory next to the peak
non-expert working set and (b) finishes before drafting ends, whichever of
compute or I/O is the bottleneck:

    N_expert = sum_{i<=L} k_i          (k_i ~= k; cached experts skipped)
    M_peak + N_expert * M_expert < M_GPU
    max((L-1)*t_comp + k_L*t_io,  N_expert*t_io) <= L_all * t_comp_draft * N_draft

The drafting budget on the right-hand side is the *whole drafting stage*
(L_all draft layers × N_draft draft tokens), matching Observation III.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class HardwareProfile:
    """Profiled system characteristics (paper's profiling module)."""
    t_comp: float            # per-layer target compute time (s)
    t_comp_draft: float      # per-layer draft compute time (s)
    t_io: float              # per-expert host->device load time (s)
    mem_gpu: float           # device memory capacity (bytes)
    mem_peak: float          # peak non-expert memory (bytes)
    mem_expert: float        # one expert's parameter bytes


@dataclass(frozen=True)
class CutoffDecision:
    cutoff_layer: int        # L: prefetch layers 0..L (inclusive); -1 = none
    n_experts: int           # total experts prefetched per iteration
    memory_bound: bool       # which constraint was binding
    overlap_bound: bool
    draft_budget: float      # drafting-stage time available for prefetch (s)
    io_time: float           # I/O time consumed at the chosen L (s)


def solve_cutoff(profile: HardwareProfile, k: int, num_layers: int,
                 draft_len: int, draft_layers: Optional[int] = None
                 ) -> CutoffDecision:
    """Maximize L subject to the paper's two constraints (k_i ~= k)."""
    draft_layers = draft_layers if draft_layers is not None else num_layers
    budget = draft_layers * profile.t_comp_draft * max(draft_len, 1)
    best = CutoffDecision(-1, 0, False, False, budget, 0.0)
    mem_free = profile.mem_gpu - profile.mem_peak
    for L in range(num_layers):
        n_expert = (L + 1) * k
        mem_ok = n_expert * profile.mem_expert < mem_free
        io_time = n_expert * profile.t_io
        pipelined = max((L - 1) * profile.t_comp_draft + k * profile.t_io, io_time)
        overlap_ok = pipelined <= budget
        if mem_ok and overlap_ok:
            best = CutoffDecision(L, n_expert, False, False, budget, io_time)
        else:
            return CutoffDecision(best.cutoff_layer, best.n_experts,
                                  not mem_ok, not overlap_ok, budget,
                                  best.io_time)
    return best


def profile_from_model(cfg, bandwidth_gbps: float = 32.0,
                       t_comp: float = 3e-3, t_comp_draft: float = 1.5e-3,
                       mem_gpu: float = 24e9,
                       mem_peak: Optional[float] = None) -> HardwareProfile:
    """Derive a HardwareProfile from a ModelConfig + link bandwidth.

    Defaults mirror the paper's RTX-4090/PCIe-4.0 profile; the dry-run uses
    TPU constants instead (launch/dryrun.py).
    """
    from repro.models.costmodel import expert_param_bytes, non_expert_bytes
    m_exp = expert_param_bytes(cfg)
    m_peak = mem_peak if mem_peak is not None else non_expert_bytes(cfg)
    return HardwareProfile(
        t_comp=t_comp, t_comp_draft=t_comp_draft,
        t_io=m_exp / (bandwidth_gbps * 1e9),
        mem_gpu=mem_gpu, mem_peak=m_peak, mem_expert=m_exp)
