"""SP-MoE offload-mode serving engine (paper-faithful runtime).

Combines every paper component end-to-end, for real, on whatever backend JAX
is running on:

  * speculative decoding (batch=1, greedy accept) — core/sd.py semantics;
  * target expert weights offloaded to a HostExpertStore; a fixed-slot
    ExpertCache with LRU lives on device;
  * drafting-stage cross-model prediction: draft gate-input taps × target
    gating networks -> prefetch tasks for layers 0..cutoff (Algorithm 1);
  * pipelined prefetching: async worker + batched I/O (Algorithm 2);
  * cached-first expert computation ordering (§4.3): the hit-experts' FFN is
    dispatched (asynchronously) while misses stream in, then the miss part is
    computed — compute/IO overlap without waiting on full availability.

Verification hot path (device-resident)
---------------------------------------
Verification latency is bounded by how well expert loading overlaps compute,
so the verify step must not re-enter the host per layer.  Two paths share
the slot-indexed grouped kernel ``kernels/cache_moe.py``:

* **fast path** — a single jitted ``lax.scan`` over all MoE layers.
  Routing (``gate_topk``), slot translation (a gather from the cache's
  device-side page table ``table_dev [L, E] -> slot | -1``), the hit mask,
  the cached-expert FFN, and the per-layer history/hit accounting all stay
  on device.  The block also computes an ``all_hit`` flag; the **only** host
  sync in the block is reading that one scalar.  If every routed expert was
  cache-resident (the common case once prefetching is warm) the block's
  logits and KV-cache update are committed as-is — together with the
  accept/reject readback in ``generate`` that is **2 host syncs per verify
  block**.  If some expert was missing, the speculative fast block is
  discarded (its KV cache is a pure-functional copy, so nothing to undo) and
  the slow path re-runs the block with on-demand loading.

* **slow path (miss resolution)** — the layer-by-layer loop: routing ids are
  read back once per layer (the miss-resolution sync), missing experts are
  fetched in cache-capacity-bounded waves while the already-dispatched
  cached-first compute proceeds underneath, and each wave's share of the
  block is added via the same slot-indexed kernel with the wave's slots
  unmasked.  A block that resolves with zero misses re-arms the fast path
  (adaptive: cold caches never pay the speculative double-compute, warm
  caches never pay per-layer syncs).

Expert weights are *never* sliced out of the resident target params on the
hot path — both paths read expert weights exclusively from the ExpertCache
slot buffers, which is what makes the offload story honest.

Baseline policies (for the paper's comparisons) plug into the same loop:
  on-demand (Mixtral-Offloading), moe-infinity (historical top-k,
  request-level, depth-unbounded), adapmoe (same-model next-layer gating,
  synchronous prefetch — always the slow path, per its design).

Host-sync accounting: every blocking device->host readback on the DECODE
path goes through ``_readback`` (a test hook — tests/test_offload_hotpath.py
spies on it to enforce the ≤2-syncs-per-block contract) and is counted in
the ``host_syncs`` counter.  Metrics-plane readbacks (each session's
device-side fast-hit accumulator, committed by ``finish_session`` once at
retirement) sit outside the decode loop and are intentionally not counted.

This engine is the *internal* offload layer: construct it from an
``EngineConfig`` (core/engine.py) — the public request/stream API is
``repro.core.engine.Engine``, which owns one OffloadEngine and serves many
requests against its warm cache.  The decode axis (greedy | sd |
sd-adaptive) is honoured here too: greedy runs 1-token verify blocks with
no drafting stage (note SP-MoE's prefetch signal IS the drafting stage, so
``greedy × spmoe`` degenerates to on-demand loading), sd-adaptive drives
the same EWMA draft-length controller as core/sd.py.

State is split into two planes so sessions can interleave on one warm
cache: everything a single request mutates lives in a :class:`DecodeState`
(KV/draft caches, position, draft-length controller, request-level
MoE-Infinity history, fast-path arming, and the device-side fast-hit
accumulator), while the engine keeps only the shared runtime (cache,
prefetcher, compiled steps) and cumulative counters.  The turn API —
``start_session`` / ``session_turn`` / ``finish_session`` — advances any
session by one committed verify block at a time; ``generate_stream`` is the
single-session wrapper, and ``Engine.serve`` (core/engine.py) is the
round-robin multi-session scheduler on top.

Batched cross-session verification: ``session_turns`` advances a whole
scheduling round at once — each ready session drafts sequentially, then
every armed session's block is verified in ONE fused dispatch
(``_verify_fast_batched``: per-session attention against each session's own
KV cache, but one concatenated [ΣT_i, ·] row batch through routing, the
``table_dev`` gather, the ``cache_moe`` kernel and the head), with ≤2 host
syncs for the whole round instead of 2·N.  Row-wise ops are bit-stable
under concatenation, so batched rounds stay lossless — bit-identical to
serving every session alone; a session that misses falls back alone to the
slow path without dragging its batchmates off the fast path.  Per-session
I/O (prefetched / evictions) is attributed to the session that caused it
via task-owner stats, not to whoever's turn an async load landed in.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import ExpertCache, ExpertKey
from repro.core.chaos import ChaosInjector, ExpertLoadError
from repro.core.cutoff import solve_cutoff
from repro.core.engine import (RUNTIME_COUNTER_KEYS, DecodePolicy,
                               EngineConfig)
from repro.core.offload import HostExpertStore
from repro.core.predictor import ExpertPredictor
from repro.core.prefetcher import Prefetcher
from repro.core import sd as S
from repro.kernels import ops
from repro.models import layers as L
from repro.models.moe import gate_topk, ffn_forward
from repro.models.transformer import DecoderLM

POLICIES = ("spmoe", "adapmoe", "moe-infinity", "on-demand")

# counters() keys — single source of truth in core/engine.py (the Engine's
# per-request delta iterates the same tuple)
_COUNTER_KEYS = RUNTIME_COUNTER_KEYS


@dataclasses.dataclass
class DecodeState:
    """The per-session plane of the offload engine: everything exactly one
    in-flight request mutates while decoding.  The engine-global plane (the
    warm ExpertCache, Prefetcher, compiled step functions, cumulative
    counters) is shared by every session; interleaving sessions block-by-
    block is safe because ``session_turn`` re-binds this state before
    touching any shared helper.

    ``history_dev`` / ``fast_ok`` / ``fast_penalty`` / ``fast_active_dev``
    used to live on the engine itself (PR 2/3) — request-level state that
    silently became engine-global.  They are per-session now: one session's
    fast-path misprediction no longer disarms another mid-block, and the
    MoE-Infinity history really is request-level, as that baseline defines
    it.  What stays global is the *warm hint* (`OffloadEngine._fast_hint`),
    seeding newly admitted sessions' arming from the shared cache's observed
    residency."""
    max_new: int
    tcache: Any
    dcache: Any = None
    cur: Optional[jax.Array] = None
    pos: int = 0
    n: int = 0                        # current draft length (0 = greedy)
    acc_ewma: float = 0.5
    emitted_total: int = 0
    pending: Optional[List[int]] = None   # prefill chunk awaiting delivery
    history_dev: Any = None           # MoE-Infinity request-level history
    fast_ok: bool = False
    fast_penalty: int = 0
    fast_active_dev: Any = None       # device-side fast-path hit accumulator
    fast_blocks: int = 0              # session's fast blocks (commit gate)
    inflight: List[Any] = dataclasses.field(default_factory=list)
    finished: bool = False
    committed: bool = False
    # owner-attributed I/O ledger: evictions this session's synchronous
    # (on-demand wave) inserts caused land here directly; its prefetch
    # tasks' stats are folded in by finish_session after done.wait().  This
    # replaces turn-window counter deltas for the per-request
    # prefetched/evictions metrics, which mis-attributed async loads landing
    # between two sessions' turns (ROADMAP open item, closed).
    io: Dict[str, int] = dataclasses.field(default_factory=lambda: {
        "prefetched": 0, "evictions": 0, "prefetch_evicted_unused": 0})


class OffloadEngine:
    def __init__(self, config: EngineConfig, tparams, dparams, *,
                 target=None, draft=None):
        """``target``/``draft`` accept the caller's already-built models
        (core/engine.py passes its own); built here when omitted.  Greedy
        decode has no drafting stage, so no draft model exists at all."""
        cfg = config.model
        assert config.offload in POLICIES, config.offload
        assert cfg.is_moe, "offload engine targets MoE models"
        self.config = config
        self.cfg = cfg
        self.policy = config.offload
        self.decode = config.decode
        self.draft_len = config.initial_draft_len
        self.max_seq = config.max_seq
        self.target = target if target is not None else DecoderLM(cfg)
        if config.needs_draft:
            self.draft = draft if draft is not None \
                else DecoderLM(config.resolved_draft())
        else:
            self.draft = None
        self.draft_cfg = self.draft.cfg if self.draft is not None else None
        self.tparams, self.dparams = tparams, dparams
        # resilience plane: one seeded fault injector shared by the store,
        # the cache and the prefetcher (None = chaos off, zero overhead)
        self.chaos = ChaosInjector(config.chaos) \
            if config.chaos is not None and config.chaos.enabled else None
        self.store = HostExpertStore(cfg, tparams, chaos=self.chaos)
        self.cache = ExpertCache(
            config.cache_slots, self.store.buffer_shapes(),
            jnp.dtype(cfg.dtype),
            table_shape=(self.store.num_layers, cfg.num_experts),
            chaos=self.chaos)
        mode = config.prefetch_mode if self.policy in ("spmoe", "moe-infinity") \
            else ("vanilla" if self.policy == "adapmoe" else "off")
        self.prefetcher = Prefetcher(
            self.store, self.cache, mode, config.batched_io,
            retries=config.prefetch_retries,
            backoff_s=config.retry_backoff_s,
            task_timeout_s=config.task_timeout_s,
            verify=config.resolved_verify_payloads,
            heartbeat_timeout_s=config.heartbeat_timeout_s,
            max_worker_restarts=config.max_worker_restarts,
            fail_threshold=config.fail_threshold,
            chaos=self.chaos)
        self.k = config.k_prefetch if config.k_prefetch is not None \
            else cfg.num_experts_per_tok
        self.predictor = ExpertPredictor(cfg, tparams, self.k)
        # cutoff layer from the analytical model (or explicit override)
        if config.cutoff is not None:
            self.cutoff = config.cutoff
        elif config.profile is not None:
            self.cutoff = solve_cutoff(config.profile, self.k,
                                       self.store.num_layers,
                                       max(self.draft_len, 1)).cutoff_layer
        else:
            self.cutoff = self.store.num_layers - 1
        # MoE-Infinity history is request-level: each DecodeState carries its
        # own device-resident [L, E] count array of this shape.
        self._hist_shape = (self.store.num_layers, cfg.num_experts)
        self._fast_traces = 0     # trace-time counter (retrace regression)
        self._batched_traces = 0  # ditto for the cross-session fused path
        self._build_jitted()
        # stats (engine-global plane: cumulative across every session)
        self.layer_hits = 0
        self.layer_lookups = 0
        self.on_demand_loads = 0
        self.host_syncs = 0
        self.verify_blocks = 0
        self.fast_blocks = 0
        self.fast_fallbacks = 0
        self.iterations = 0
        self.drafted = 0
        self.accepted = 0
        # round-level accounting for the batched cross-session scheduler
        # (bench metrics, not part of counters()): verify_rounds counts
        # session_turns rounds that verified at least one block,
        # round_launches the verify dispatches those rounds needed — 1 fused
        # launch per all-hit round regardless of how many sessions it served.
        self.verify_rounds = 0
        self.round_launches = 0
        # graceful-degradation ladder: while the prefetch plane is unhealthy
        # (worker dead beyond its restart budget, wedged past its heartbeat,
        # or circuit-breaker open on failure pressure) the offload policy
        # steps down to on-demand synchronous loading — _prefetch() submits
        # nothing, the slow path's miss waves carry the load — and steps
        # back up when health returns.  Tokens are never wrong, only slower;
        # only a synchronous load that ITSELF exhausts its retry budget ends
        # the one owning request with finish_reason="io_error".
        self._degraded = False
        self.degraded_rounds = 0
        self.io_errors = 0
        # adaptive fast-path arming is per-session (DecodeState.fast_ok):
        # cold caches go straight to the slow (miss-resolving) path; a
        # zero-miss slow block re-arms, and after a misprediction
        # fast_penalty demands that many consecutive clean slow blocks
        # before re-arming.  _fast_hint is the engine-global residual: the
        # last observed arming state of the shared cache, used only to seed
        # NEWLY admitted sessions (so request 2 on a warm engine starts on
        # the fast path instead of paying per-layer syncs to rediscover
        # warmth).
        self._fast_hint = False
        self._st: Optional[DecodeState] = None   # state bound to this turn
        if config.precompile and self.policy != "adapmoe":
            self._precompile_fast()

    # ------------------------------------------------------------------ sync
    def _readback(self, x):
        """The ONLY device->host sync point in the engine.  Every blocking
        transfer funnels through here so tests can spy on it and the stats
        report an honest host-sync count."""
        self.host_syncs += 1
        return np.asarray(x)

    # ------------------------------------------------------------------ jit
    def _build_jitted(self):
        cfg = self.cfg
        mp = self.tparams["layers"]

        def attn_half(lp, x, cache_l, pos):
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, cache_l = L.mla_decode(lp["attn"], h, cache_l, pos, cfg)
            else:
                a, cache_l = L.attention_decode(lp["attn"], h, cache_l, pos, cfg)
            x = x + a
            h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            return x, cache_l, h2

        def gate_fn(gate_w, h2):
            w, ids, probs, _ = gate_topk(gate_w, h2.reshape(-1, cfg.d_model),
                                         cfg.num_experts_per_tok)
            return w, ids, probs

        def cached_moe_apply(bufs, x, slot_ids, weights):
            """x: [T,d]; slot_ids/weights: [T,k] -> [T,d].  Slot-indexed
            grouped kernel over the cache pool; slot_ids < 0 contribute 0 —
            the hit/miss/wave split is pure masking, no gather on host."""
            return ops.cache_moe(x, slot_ids, weights,
                                 bufs["wu"], bufs["wd"], bufs.get("wg"))

        def shared_and_residual(lp, x, h2, y_experts):
            if cfg.num_shared_experts:
                y_experts = y_experts + ffn_forward(lp["moe"]["shared"], h2, "swiglu")
            return x + y_experts

        def dense_block(lp, x, cache_l, pos):
            x, cache_l, h2 = attn_half(lp, x, cache_l, pos)
            y = ffn_forward(lp["ffn"], h2, cfg.ffn_activation)
            return x + y, cache_l

        def embed(tokens):
            return jnp.take(self.tparams["wte"], tokens, axis=0)

        def head(x):
            xf = L.rms_norm(x, self.tparams["ln_f"], cfg.norm_eps)
            if cfg.tie_embeddings:
                return jnp.einsum("bsd,vd->bsv", xf, self.tparams["wte"])
            return jnp.einsum("bsd,dv->bsv", xf, self.tparams["head"])

        # per-MoE-layer params *without* the resident expert weights: the hot
        # path must only ever read experts from the cache slot buffers.
        lp_scan: Dict[str, Any] = {"ln1": mp["ln1"], "ln2": mp["ln2"],
                                   "attn": mp["attn"],
                                   "gate": mp["moe"]["gate"]}
        if cfg.num_shared_experts:
            lp_scan["shared"] = mp["moe"]["shared"]

        def dense_stack(x, dcache, pos):
            def dbody(carry, xs):
                lp, cl = xs
                xo, ncl = dense_block(lp, carry, cl, pos)
                return xo, ncl
            return jax.lax.scan(dbody, x,
                                (self.tparams["dense_layers"], dcache))

        def verify_fast(bufs, table, history, tokens, pos, tcache):
            """Whole verify block as ONE device computation (lax.scan over
            the stacked MoE layers), speculating that every routed expert is
            cache-resident.  Returns (logits, all_hit, new_tcache,
            new_history, n_active); nothing here syncs to host."""
            self._fast_traces += 1        # trace-time side effect only
            x = embed(tokens)
            T = tokens.shape[1]
            new_tcache = dict(tcache)
            if "dense_layers" in self.tparams:
                x, new_tcache["dense_layers"] = dense_stack(
                    x, tcache["dense_layers"], pos)

            def mbody(carry, xs):
                x, ok, nact = carry
                lp, cl, trow = xs
                x2, ncl, h2 = attn_half(lp, x, cl, pos)
                w, ids, _ = gate_fn(lp["gate"], h2)
                slot_ids = trow[ids]                      # [T,k]; -1 = miss
                hit = slot_ids >= 0
                ok = jnp.logical_and(ok, jnp.all(hit))
                y = cached_moe_apply(bufs, h2.reshape(T, cfg.d_model),
                                     slot_ids, jnp.where(hit, w, 0.0))
                y3 = y.reshape(1, T, cfg.d_model)
                if cfg.num_shared_experts:
                    y3 = y3 + ffn_forward(lp["shared"], h2, "swiglu")
                activated = jnp.zeros((cfg.num_experts,), jnp.int32
                                      ).at[ids.reshape(-1)].add(1) > 0
                nact = nact + jnp.sum(activated.astype(jnp.float32))
                return (x2 + y3, ok, nact), (ncl, activated)

            (x, ok, nact), (nlayers, act) = jax.lax.scan(
                mbody, (x, jnp.bool_(True), jnp.float32(0.0)),
                (lp_scan, tcache["layers"], table))
            new_tcache["layers"] = nlayers
            new_history = history + act.astype(history.dtype)
            return head(x), ok, new_tcache, new_history, nact

        def verify_fast_batched(bufs, table, hists, tokens, pos, tcaches):
            """Whole scheduling ROUND as one device computation: every ready
            session's verify block in a single fused dispatch.  ``tokens`` is
            a tuple of [1, T_i] blocks (T_i may be ragged), ``hists`` /
            ``pos`` / ``tcaches`` the matching per-session state tuples.

            Attention stays per-session (each has its own KV cache and
            position — identical shapes and ops to the solo fast path, so
            per-session results are bit-identical to serving it alone), while
            everything row-wise is concatenated into one [ΣT_i, ·] batch:
            ONE routing pass, ONE ``table_dev`` gather, ONE ``cache_moe``
            launch and ONE head projection per layer-scan, instead of one
            each per session.  Row-wise ops are bit-stable under
            concatenation (each row's reduction order is independent of the
            batch), which is what makes batched rounds lossless.

            Returns (logits [1, ΣT, V], ok [N] per-session all-hit flags,
            new_tcaches, new_hists, nact [N]); nothing here syncs to host.
            A session that misses falls back alone — the caller commits its
            batchmates' results and re-runs only that session's block on the
            slow path."""
            self._batched_traces += 1     # trace-time side effect only
            n = len(tokens)
            Ts = tuple(int(t.shape[1]) for t in tokens)
            offs = [0]
            for t in Ts:
                offs.append(offs[-1] + t)
            new_tcaches = [dict(tc) for tc in tcaches]
            xs = []
            for i in range(n):
                x = embed(tokens[i])
                if "dense_layers" in self.tparams:
                    x, new_tcaches[i]["dense_layers"] = dense_stack(
                        x, tcaches[i]["dense_layers"], pos[i])
                xs.append(x)

            def mbody(carry, scan_xs):
                xs_c, ok_c, nact_c = carry
                lp, cls, trow = scan_xs
                x2s, ncls, h2s = [], [], []
                for i in range(n):
                    x2, ncl, h2 = attn_half(lp, xs_c[i], cls[i], pos[i])
                    x2s.append(x2)
                    ncls.append(ncl)
                    h2s.append(h2)
                h2cat = jnp.concatenate(
                    [h2s[i].reshape(Ts[i], cfg.d_model) for i in range(n)])
                w, ids, _ = gate_fn(lp["gate"], h2cat)    # ONE routing pass
                slot_ids = trow[ids]                      # [ΣT, k]; -1 = miss
                hit = slot_ids >= 0
                ycat = cached_moe_apply(bufs, h2cat, slot_ids,
                                        jnp.where(hit, w, 0.0))
                outs, oks, nacts, acts = [], [], [], []
                for i in range(n):
                    r0, r1 = offs[i], offs[i + 1]
                    y3 = ycat[r0:r1].reshape(1, Ts[i], cfg.d_model)
                    if cfg.num_shared_experts:
                        y3 = y3 + ffn_forward(lp["shared"], h2s[i], "swiglu")
                    outs.append(x2s[i] + y3)
                    oks.append(jnp.logical_and(ok_c[i],
                                               jnp.all(hit[r0:r1])))
                    activated = jnp.zeros((cfg.num_experts,), jnp.int32
                                          ).at[ids[r0:r1].reshape(-1)
                                               ].add(1) > 0
                    acts.append(activated)
                    nacts.append(nact_c[i] +
                                 jnp.sum(activated.astype(jnp.float32)))
                return (tuple(outs), tuple(oks), tuple(nacts)), \
                    (tuple(ncls), tuple(acts))

            carry0 = (tuple(xs),
                      tuple(jnp.bool_(True) for _ in range(n)),
                      tuple(jnp.float32(0.0) for _ in range(n)))
            (xs_f, ok_f, nact_f), (nlayers, acts) = jax.lax.scan(
                mbody, carry0,
                (lp_scan, tuple(tc["layers"] for tc in tcaches), table))
            for i in range(n):
                new_tcaches[i]["layers"] = nlayers[i]
            new_hists = tuple(hists[i] + acts[i].astype(hists[i].dtype)
                              for i in range(n))
            xcat = jnp.concatenate(xs_f, axis=1)          # [1, ΣT, d]
            return (head(xcat), jnp.stack(ok_f), tuple(new_tcaches),
                    new_hists, jnp.stack(nact_f))

        self._attn_half = jax.jit(attn_half)
        self._gate = jax.jit(gate_fn)
        self._moe_apply = jax.jit(cached_moe_apply)
        self._shared_res = jax.jit(shared_and_residual)
        self._dense_stack = jax.jit(dense_stack)
        self._embed = jax.jit(embed)
        self._head = jax.jit(head)
        self._verify_fast = jax.jit(verify_fast)
        self._verify_fast_batched = jax.jit(verify_fast_batched)
        # fixed-shape masked row add: one executable regardless of how many
        # experts a layer activated (a [E]-gather scatter would retrace per
        # distinct unique-count)
        self._hist_add = jax.jit(lambda h, l, mask: h.at[l].add(mask))
        self._draft_step = (jax.jit(functools.partial(
            self.draft.decode_step, collect_taps=True))
            if self.draft is not None else None)

    def _precompile_fast(self):
        """Trace + compile ``_verify_fast`` for every decode block shape this
        config can produce, so no armed fast block ever holds the cache lock
        across a trace (ROADMAP open item).  ``sd`` / ``greedy`` have one
        block shape ([1, N+1]); ``sd-adaptive`` pre-traces the whole
        draft-length ladder [min_draft_len, max_draft_len] — previously only
        ``min_draft_len + 1`` was compiled and every distinct adapted length
        retraced mid-serve.  The dummy calls' inputs mirror the decode-time
        signature exactly — int32 tokens, a python-int position, the
        session-shaped KV cache — so the jit cache entries are the ones
        ``_verify_block`` hits (regressions:
        tests/test_engine.py::test_no_retrace_on_second_fast_block,
        tests/test_sessions.py::test_adaptive_ladder_precompiled)."""
        if self.decode == DecodePolicy.SD_ADAPTIVE.value:
            lens = range(self.config.min_draft_len,
                         self.config.max_draft_len + 1)
        else:
            lens = (self.draft_len,)
        tcache = self.target.init_cache(1, self.max_seq)
        bufs, table = self.cache.snapshot()   # init: nothing inserts yet
        hist = jnp.zeros(self._hist_shape, jnp.float32)
        for n in lens:
            tokens = jnp.zeros((1, n + 1), jnp.int32)
            self._verify_fast(bufs, table, hist, tokens, 0, tcache)

    def _layer_params(self, l: int):
        """Per-layer param slice for the slow path — attention + norms +
        gate (+ shared experts), explicitly NOT the resident expert weights."""
        mp = self.tparams["layers"]
        moe_small: Dict[str, Any] = {"gate": mp["moe"]["gate"][l]}
        if self.cfg.num_shared_experts:
            moe_small["shared"] = jax.tree.map(lambda a: a[l],
                                               mp["moe"]["shared"])
        return {"ln1": jax.tree.map(lambda a: a[l], mp["ln1"]),
                "ln2": jax.tree.map(lambda a: a[l], mp["ln2"]),
                "attn": jax.tree.map(lambda a: a[l], mp["attn"]),
                "moe": moe_small}

    # ------------------------------------------------------------- verification
    def _ensure_loaded(self, layer: int, ids: np.ndarray
                       ) -> Tuple[Dict[ExpertKey, int], List[ExpertKey]]:
        keys = [(layer, int(e)) for e in dict.fromkeys(ids.ravel().tolist())]
        hits, misses = self.cache.lookup(keys)
        self.layer_lookups += len(keys)
        self.layer_hits += len(hits)
        return hits, misses

    # -------------------------------------------------------------- resilience
    def _check_health(self):
        """One degradation-ladder step, run once per scheduling round:
        probe-and-repair the prefetch plane (restart a dead worker within
        budget, release stranded tasks past it) and step the offload policy
        down to on-demand synchronous loading while the plane is unhealthy.
        Health returning steps back up automatically — ``_degraded`` is
        recomputed every round, never latched."""
        if self.prefetcher.mode == "off":
            self._degraded = False
        else:
            self._degraded = not self.prefetcher.revive()

    def health(self) -> str:
        """Ladder position: ``"healthy"`` (prefetch plane trusted),
        ``"degraded"`` (on-demand synchronous loads; expected to recover),
        or ``"failed"`` (worker permanently gone — restart budget spent)."""
        if not self._degraded:
            return "healthy"
        pf = self.prefetcher
        if pf.mode == "worker" and not pf.worker_alive() \
                and pf.worker_restarts >= pf.max_worker_restarts:
            return "failed"
        return "degraded"

    def _load_wave(self, wave: List[ExpertKey], st: DecodeState) -> List[int]:
        """Decode-critical on-demand load: fetch + insert one miss wave
        under a bounded retry budget (``io_retries``), with checksum
        verification when enabled.  The FINAL attempt runs inside the chaos
        injector's ``calm()`` scope, so *injected* faults can never exhaust
        this budget — losslessness under chaos is a guarantee.  A real
        fault that survives every retry raises :class:`ExpertLoadError`:
        the degradation ladder's last rung, ending the one owning request
        with ``finish_reason="io_error"`` (never wrong tokens)."""
        attempts = self.config.io_retries + 1
        verify = self.prefetcher.verify
        last: Optional[BaseException] = None
        for a in range(attempts):
            calm = self.chaos.calm() if self.chaos is not None \
                and a == attempts - 1 else contextlib.nullcontext()
            try:
                with calm:
                    arrays = self.store.fetch_verified(wave) if verify \
                        else self.store.fetch(wave)
                    return self.cache.insert(wave, arrays, mark_used=True,
                                             stats=st.io)
            except OSError as e:           # ChaosError/PayloadCorruption too
                last = e
                if a < attempts - 1:
                    time.sleep(self.config.retry_backoff_s * (2 ** a))
        self.io_errors += 1
        raise ExpertLoadError(
            f"on-demand load of {len(wave)} experts failed after "
            f"{attempts} attempts: {last}") from last

    def _verify_block(self, tokens: jax.Array, pos: int, tcache):
        """Layer-wise target forward with cache-aware expert compute.
        tokens: [1, N+1].  See module docstring for the fast/slow design.
        Session state (fast-path arming, history, hit accumulator) is read
        from ``self._st`` — bound by the turn that dispatched this block —
        so the signature stays the sync-spy hook tests wrap."""
        st = self._st
        self.verify_blocks += 1
        if st.fast_ok and self.policy != "adapmoe":
            # snapshot + dispatch under the cache lock: a concurrent donating
            # insert must not delete the buffer handle mid-dispatch.
            with self.cache.lock:
                bufs, table = self.cache.snapshot()
                logits, ok, ncache, nhist, nact = self._verify_fast(
                    bufs, table, st.history_dev, tokens, pos, tcache)
            if bool(self._readback(ok)):          # sync 1 of ≤2 per block
                st.history_dev = nhist
                st.fast_active_dev = st.fast_active_dev + nact
                st.fast_blocks += 1
                self.fast_blocks += 1
                return logits, ncache
            st.fast_ok = False                    # mispredicted availability
            st.fast_penalty = 2
            self._fast_hint = False
            self.fast_fallbacks += 1
        return self._verify_block_slow(tokens, pos, tcache)

    def _verify_block_slow(self, tokens: jax.Array, pos: int, tcache):
        """Miss-resolution path: per-layer loop, one routing readback per MoE
        layer, on-demand wave loading; re-arms the session's fast path when
        the whole block resolved from cache."""
        st = self._st
        cfg = self.cfg
        x = self._embed(tokens)
        T = tokens.shape[1]
        total_misses = 0
        if "dense_layers" in self.tparams:
            x, tcache["dense_layers"] = self._dense_stack(
                x, tcache["dense_layers"], pos)
        new_layers = []
        for l in range(self.store.num_layers):
            lp = self._layer_params(l)
            cl = jax.tree.map(lambda a: a[l], tcache["layers"])
            x, ncl, h2 = self._attn_half(lp, x, cl, pos)
            new_layers.append(ncl)
            w, ids, probs = self._gate(lp["moe"]["gate"], h2)
            ids_np = self._readback(ids)          # miss-resolution sync
            act = np.zeros((cfg.num_experts,), np.float32)
            act[np.unique(ids_np)] = 1.0
            st.history_dev = self._hist_add(st.history_dev, l,
                                            jnp.asarray(act))
            # AdapMoE baseline: predict next layer from *this* layer's gate
            # input using the target's own gates, synchronous prefetch.
            if self.policy == "adapmoe" and l + 1 < self.store.num_layers:
                nxt = self.predictor.predict_layer(l + 1, h2[:, -1:])
                _, miss = self.cache.lookup(nxt, touch=False)
                if miss:
                    self._prefetch(st, miss)         # vanilla mode: blocking
            hits, misses = self._ensure_loaded(l, ids_np)
            total_misses += len(misses)
            # cached-first compute (dispatches async under jax): hit experts'
            # slots unmasked, everything else -1
            slot_lut = np.full((cfg.num_experts,), -1, np.int64)
            for (_, e), s in hits.items():
                slot_lut[e] = s
            xf = h2.reshape(T, cfg.d_model)
            with self.cache.lock:
                bufs, _ = self.cache.snapshot()
                y = self._moe_apply(bufs, xf,
                                    jnp.asarray(slot_lut[ids_np], jnp.int32), w)
            if misses:
                # on-demand batched loads, in cache-capacity-bounded waves:
                # each wave's experts are loaded (evicting as needed — the
                # hit experts' compute is already dispatched) and its share
                # of the block is computed before the next wave streams in.
                self.on_demand_loads += len(misses)
                wave_size = max(1, self.cache.num_slots)
                for w0 in range(0, len(misses), wave_size):
                    wave = misses[w0:w0 + wave_size]
                    slots = self._load_wave(wave, st)
                    wave_lut = np.full((cfg.num_experts,), -1, np.int64)
                    for (key, s) in zip(wave, slots):
                        wave_lut[key[1]] = s
                    with self.cache.lock:
                        bufs, _ = self.cache.snapshot()
                        y = y + self._moe_apply(
                            bufs, xf,
                            jnp.asarray(wave_lut[ids_np], jnp.int32), w)
            x = self._shared_res(lp, x, h2, y.reshape(1, T, cfg.d_model))
        tcache["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
        if self.policy != "adapmoe":
            if total_misses == 0:
                if st.fast_penalty > 0:
                    st.fast_penalty -= 1
                st.fast_ok = st.fast_penalty == 0
            else:
                st.fast_ok = False
            self._fast_hint = st.fast_ok   # seed arming of future sessions
        return self._head(x), tcache

    # ------------------------------------------------------------ session API
    # The turn-based serving surface: a scheduler (core/engine.py's
    # Engine.serve round-robin, or the generate_stream wrapper below for a
    # single session) admits a session with start_session, advances it one
    # committed verify block at a time with session_turn, and retires it
    # with finish_session.  All three re-bind self._st, so any number of
    # sessions may interleave turns on the one warm cache.

    def start_session(self, prompt: jax.Array, max_new_tokens: int
                      ) -> DecodeState:
        """Admit one request: allocate its per-session plane (KV cache,
        draft cache, request-level history, fast-path arming seeded from the
        engine's warm hint) and run the prefill verify block — through the
        cache-aware path, so its expert loads warm the shared cache."""
        assert prompt.shape[0] == 1
        st = DecodeState(
            max_new=max_new_tokens,
            tcache=self.target.init_cache(1, self.max_seq),
            n=self.draft_len,                     # 0 for greedy decode
            history_dev=jnp.zeros(self._hist_shape, jnp.float32),
            fast_active_dev=jnp.zeros((), jnp.float32),
            fast_ok=self._fast_hint and self.policy != "adapmoe")
        if max_new_tokens <= 0:
            st.finished = True
            return st
        self._st = st
        if st.n > 0:
            _, st.dcache = self.draft.prefill(self.dparams, prompt,
                                              self.max_seq)
        logits, st.tcache = self._verify_block(prompt, 0, st.tcache)
        st.cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        st.pos = prompt.shape[1]
        st.emitted_total = 1
        st.pending = [int(st.cur[0, 0])]
        return st

    # sentinel: _turn_early found nothing to deliver — the turn must draft
    # and verify (None is a real return value, "session done")
    _NEEDS_VERIFY = object()

    def _turn_early(self, st: DecodeState):
        """The no-verify turn outcomes: session already done (None), prefill
        chunk awaiting delivery (the chunk), or token budget exhausted
        (None).  Returns ``_NEEDS_VERIFY`` when a verify block is due."""
        if st.finished:
            return None
        if st.pending is not None:             # deliver the prefill token
            chunk, st.pending = st.pending, None
            st.finished = st.emitted_total >= st.max_new
            return chunk
        if st.emitted_total >= st.max_new:
            st.finished = True
            return None
        return self._NEEDS_VERIFY

    def _turn_draft(self, st: DecodeState
                    ) -> Tuple[List[int], jax.Array]:
        """Prefetch-signal + drafting stage of one turn: MoE-Infinity
        history prefetch, the draft loop with SP-MoE speculative prefetching,
        and the assembled verify block.  Returns (drafts, block [1, N+1])."""
        N = st.n
        # MoE-Infinity: request-level historical prefetch, all layers
        if self.policy == "moe-infinity":
            hist = self._readback(st.history_dev)
            for l in range(self.store.num_layers):
                top = np.argsort(-hist[l])[: self.k]
                keys = [(l, int(e)) for e in top]
                # while the fast verify path is armed it never touches the
                # LRU itself (that would need a device readback), so
                # predicted-hot experts carry the recency signal instead
                _, miss = self.cache.lookup(keys, touch=st.fast_ok)
                if miss:
                    self._prefetch(st, miss)
        # ---- drafting stage (+ SP-MoE speculative prefetching) ----
        drafts = []
        tok = st.cur
        for i in range(N):
            lg, st.dcache, taps = self._draft_step(
                self.dparams, st.dcache, tok, jnp.int32(st.pos + i))
            tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
            drafts.append(int(tok[0, 0]))
            if self.policy == "spmoe" and self.cutoff >= 0:
                tap_stack = self._draft_taps_for_moe(taps)
                for l in range(min(self.cutoff + 1, self.store.num_layers)):
                    keys = self.predictor.predict_layer(l, tap_stack[l])
                    # see moe-infinity note: predictions substitute for LRU
                    # touches while the fast path is armed
                    _, miss = self.cache.lookup(keys, touch=st.fast_ok)
                    if miss:
                        self._prefetch(st, miss)
        block = jnp.concatenate(
            [st.cur, jnp.asarray([drafts], jnp.int32)], axis=1) \
            if drafts else st.cur
        return drafts, block

    def _turn_commit(self, st: DecodeState, drafts: List[int],
                     greedy: np.ndarray) -> List[int]:
        """Accept/commit stage: greedy is the verified block's argmax row
        ([N+1] host ints).  Identical whether the block was verified solo or
        as a slice of a batched cross-session round."""
        cfg = self.config
        N = len(drafts)
        d = np.asarray(drafts, np.int64)
        match = d == greedy[:N]
        n_acc = int(np.cumprod(match.astype(np.int64)).sum())
        emitted = [int(t) for t in d[:n_acc]] + [int(greedy[n_acc])]
        st.cur = jnp.asarray([[int(greedy[n_acc])]], jnp.int32)
        st.pos += n_acc + 1
        self.iterations += 1
        self.drafted += N
        self.accepted += n_acc
        if self.decode == DecodePolicy.SD_ADAPTIVE.value:
            st.n, st.acc_ewma = S.adaptive_next_len(
                N, n_acc, st.acc_ewma, cfg.min_draft_len,
                cfg.max_draft_len, cfg.draft_ewma)
        chunk = emitted[:st.max_new - st.emitted_total]
        st.emitted_total += len(chunk)
        st.finished = st.emitted_total >= st.max_new
        return chunk

    def session_turn(self, st: DecodeState) -> Optional[List[int]]:
        """Advance one session by ONE committed chunk; returns the chunk
        (clipped to the max_new_tokens budget) or None once the session has
        nothing left to emit.  The block schedule is decode-policy-aware:
        greedy = a 1-token block with no drafting stage, sd = a fixed-N
        draft-then-verify block, sd-adaptive = the EWMA controller of
        core/sd.py driving this session's own draft length."""
        early = self._turn_early(st)
        if early is not self._NEEDS_VERIFY:
            return early
        self._check_health()                 # one ladder step per turn
        if self._degraded:
            self.degraded_rounds += 1
        self._st = st
        drafts, block = self._turn_draft(st)
        try:
            tlogits, st.tcache = self._verify_block(block, st.pos, st.tcache)
        except ExpertLoadError:
            # the ladder's last rung: this session cannot make progress
            # without the failed load — end it (the caller maps this to
            # finish_reason="io_error"); batchmates are unaffected.
            st.finished = True
            raise
        greedy = self._readback(jnp.argmax(tlogits, -1))[0]      # accept
        return self._turn_commit(st, drafts, greedy)

    def _counter_delta(self, before: Dict[str, int]) -> Dict[str, int]:
        after = self.counters()
        return {k: after[k] - before[k] for k in _COUNTER_KEYS}

    @staticmethod
    def _merge_delta(into: Dict[str, int], delta: Dict[str, int]):
        for k, v in delta.items():
            into[k] = into.get(k, 0) + v

    def session_turns(self, sts: Sequence[DecodeState]
                      ) -> List[Tuple[Optional[List[int]], Dict[str, int],
                                      float]]:
        """Advance SEVERAL sessions by one committed verify block each in a
        single scheduling round, verifying the ready sessions' blocks with
        ONE fused fast-path dispatch (``_verify_fast_batched``): one routing
        pass, one ``table_dev`` gather, one ``cache_moe`` launch, and ≤2
        host syncs for the whole round — the per-session all-hit vector and
        the accept/reject argmax, each read back once — instead of 2·N.

        Per-session drafting (and its prefetch submissions) stays
        sequential ahead of the fused verify; sessions whose fast path is
        not armed (cold cache, post-misprediction penalty, adapmoe) verify
        solo on their usual path, and a batched session whose block missed
        falls back ALONE to the slow miss-resolving path — its batchmates'
        fused results commit untouched.  Per-session results are
        bit-identical to ``session_turn`` serving each session by itself.

        Returns one ``(chunk, counter_delta, wall_s)`` triple per session:
        ``counter_delta`` attributes this round's cumulative-counter growth
        to the session that caused it (the ≤2 shared round syncs are charged
        to the round's first fused session so the per-request ledgers still
        tile the cumulative counters exactly), and ``wall_s`` is the decode
        time this session's own phases took — a fallback's slow re-run is
        charged to the session that missed, only the genuinely shared fused
        dispatch is split evenly across its members."""
        # a chunk is List[int], None (session done), or an ExpertLoadError
        # instance (session ended by the ladder's io_error rung)
        chunks: List[Any] = [None] * len(sts)
        deltas: List[Dict[str, int]] = [{} for _ in sts]
        walls: List[float] = [0.0] * len(sts)
        pend: List[Tuple[int, DecodeState, List[int], jax.Array]] = []
        self._check_health()                 # one ladder step per round
        degraded_counted = False
        for i, st in enumerate(sts):
            before = self.counters()
            t0 = time.perf_counter()
            early = self._turn_early(st)
            if early is not self._NEEDS_VERIFY:
                chunks[i] = early
                deltas[i] = self._counter_delta(before)
                walls[i] += time.perf_counter() - t0
                continue
            self._st = st
            if self._degraded and not degraded_counted:
                # charge the round's one degraded tick to the round's first
                # verifying session, INSIDE its delta window, so the
                # per-request ledgers still tile the cumulative counter
                degraded_counted = True
                self.degraded_rounds += 1
            drafts, block = self._turn_draft(st)
            deltas[i] = self._counter_delta(before)
            walls[i] += time.perf_counter() - t0
            pend.append((i, st, drafts, block))
        if pend:
            self.verify_rounds += 1
        fused = [p for p in pend
                 if p[1].fast_ok and self.policy != "adapmoe"]
        if len(fused) >= 2:
            # canonical order: sort by block length (stable, so ties keep
            # admission order) — (4,6) and (6,4) rounds then share ONE
            # fused-trace signature instead of retracing per permutation of
            # sd-adaptive's ragged lengths.  Concat order is transparent to
            # each session's results (row-stable ops), so this is lossless.
            fused.sort(key=lambda p: p[3].shape[1])
            fused_idx = {p[0] for p in fused}
            solo = [p for p in pend if p[0] not in fused_idx]
            self._round_fused(fused, chunks, deltas, walls)
        else:
            solo = pend
        for i, st, drafts, block in solo:
            before = self.counters()
            t0 = time.perf_counter()
            self._st = st
            self.round_launches += 1
            try:
                tlogits, st.tcache = self._verify_block(block, st.pos,
                                                        st.tcache)
                greedy = self._readback(jnp.argmax(tlogits, -1))[0]
                chunks[i] = self._turn_commit(st, drafts, greedy)
            except ExpertLoadError as e:
                # ladder's last rung: end ONLY this session — batchmates'
                # turns proceed.  The scheduler maps the exception chunk to
                # finish_reason="io_error" (see engine.Session.deliver).
                st.finished = True
                chunks[i] = e
            self._merge_delta(deltas[i], self._counter_delta(before))
            walls[i] += time.perf_counter() - t0
        return list(zip(chunks, deltas, walls))

    def _round_fused(self, fused, chunks, deltas, walls):
        """The fused leg of one scheduling round: dispatch every armed
        session's block in one ``_verify_fast_batched`` call, read the
        per-session all-hit vector and the round's accept/reject argmax back
        once each, then commit hits / re-run misses per session."""
        idxs = [p[0] for p in fused]
        sts = [p[1] for p in fused]
        blocks = [p[3] for p in fused]
        offs = [0]
        for b in blocks:
            offs.append(offs[-1] + b.shape[1])
        self.round_launches += 1
        t0 = time.perf_counter()
        # snapshot + dispatch under the cache lock: a concurrent donating
        # insert must not delete the buffer handle mid-dispatch.
        with self.cache.lock:
            bufs, table = self.cache.snapshot()
            logits, ok_vec, new_tcaches, new_hists, nact_vec = \
                self._verify_fast_batched(
                    bufs, table,
                    tuple(st.history_dev for st in sts),
                    tuple(blocks),
                    tuple(st.pos for st in sts),
                    tuple(st.tcache for st in sts))
        ok = self._readback(ok_vec)                 # round sync 1 of ≤2
        greedy = self._readback(jnp.argmax(logits, -1))[0]   # round sync 2
        shared = (time.perf_counter() - t0) / len(fused)
        for i in idxs:      # the fused dispatch is genuinely shared work
            walls[i] += shared
        deltas[idxs[0]]["host_syncs"] = \
            deltas[idxs[0]].get("host_syncs", 0) + 2
        for j, (i, st, drafts, _) in enumerate(fused):
            before = self.counters()
            t0 = time.perf_counter()
            self._st = st
            self.verify_blocks += 1
            if bool(ok[j]):
                st.history_dev = new_hists[j]
                st.fast_active_dev = st.fast_active_dev + nact_vec[j]
                st.fast_blocks += 1
                self.fast_blocks += 1
                st.tcache = new_tcaches[j]
                chunks[i] = self._turn_commit(
                    st, drafts, greedy[offs[j]:offs[j + 1]])
            else:
                # mispredicted availability: this session falls back alone;
                # its speculative tcache/history copies are discarded
                st.fast_ok = False
                st.fast_penalty = 2
                self._fast_hint = False
                self.fast_fallbacks += 1
                self.round_launches += 1
                try:
                    tlogits, st.tcache = self._verify_block_slow(
                        blocks[j], st.pos, st.tcache)
                    g = self._readback(jnp.argmax(tlogits, -1))[0]
                    chunks[i] = self._turn_commit(st, drafts, g)
                except ExpertLoadError as e:
                    # end ONLY this session; its fused batchmates committed
                    st.finished = True
                    chunks[i] = e
            self._merge_delta(deltas[i], self._counter_delta(before))
            walls[i] += time.perf_counter() - t0

    def _prefetch(self, st: DecodeState, keys):
        """Submit a prefetch on behalf of ``st``, remembering the task so
        retirement waits on exactly this session's in-flight I/O.  While the
        ladder is degraded the prefetch plane is not trusted: submit nothing
        and let the slow path's on-demand waves carry the load."""
        if self._degraded:
            return
        task = self.prefetcher.submit(keys)
        if task is not None:
            st.inflight.append(task)

    def finish_session(self, st: DecodeState):
        """Retire a session (idempotent, runs on every exit path): commit
        its device-side fast-path hit accumulator into the cumulative
        lookup/hit counters — the ONE metrics-plane readback per session,
        off the decode path, hence deliberately not routed through
        ``_readback`` — and wait out the session's OWN prefetch tasks so
        none is in flight against a retired request's predictions.  Only
        this session's tasks: a full ``prefetcher.drain()`` here would
        stall still-active concurrent sessions on the shared worker at
        every retirement boundary."""
        if st.committed:
            return
        st.committed = True
        st.finished = True
        if st.fast_blocks:
            fast_active = int(np.asarray(st.fast_active_dev))
            self.layer_lookups += fast_active
            self.layer_hits += fast_active
        for task in st.inflight:       # worker sets done even on task error
            # bounded wait that pumps the prefetcher's probe-and-repair
            # (revive / abandon_pending), so a dead-and-unrestartable worker
            # can never strand retirement on a task nobody will ever run
            if self.prefetcher.wait_task(
                    task, timeout=self.config.drain_timeout_s):
                for k, v in task.stats.items():  # owner-attributed I/O: the
                    st.io[k] = st.io.get(k, 0) + v  # task is THIS session's
        st.inflight.clear()
        self.cache.wait()              # dispatched H2D transfers have landed

    # ---------------------------------------------------------------- generate
    def generate_stream(self, prompt: jax.Array, max_new_tokens: int
                        ) -> Iterator[List[int]]:
        """Single-session streaming wrapper over the session API: yields one
        List[int] chunk per committed verify block.  Cumulative engine
        counters update per turn, so an early generator close (stop token,
        abandoned consumer) leaves consistent stats; the session is retired
        (fast-hit commit + wait on its own prefetch tasks) on every exit
        path."""
        if max_new_tokens <= 0:
            return
        st = self.start_session(prompt, max_new_tokens)
        try:
            while True:
                chunk = self.session_turn(st)
                if chunk is None:
                    return
                yield chunk
        finally:
            self.finish_session(st)

    def generate(self, prompt: jax.Array, max_new_tokens: int
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One-shot compat wrapper over ``generate_stream`` returning the
        legacy (tokens, stats-dict) shape; stats are this call's counter
        deltas (identical to the old cumulative dict on a fresh engine)."""
        before = self.counters()
        t0 = time.perf_counter()
        out: List[int] = []
        for chunk in self.generate_stream(prompt, max_new_tokens):
            out.extend(chunk)
        dt = time.perf_counter() - t0
        after = self.counters()
        d = {k: after[k] - before[k] for k in _COUNTER_KEYS}
        stats = {
            "wall_s": dt,
            "tpot_wall": dt / max(len(out), 1),
            "iterations": d["iterations"],
            "acceptance_rate": d["accepted"] / max(d["drafted"], 1),
            "hit_rate": d["hits"] / max(d["lookups"], 1),
            "on_demand_loads": d["on_demand_loads"],
            "prefetched": d["prefetched"],
            "evictions": d["evictions"],
            "prefetch_evicted_unused": d["prefetch_evicted_unused"],
            "cutoff_layer": self.cutoff,
            "host_syncs": d["host_syncs"],
            "verify_blocks": d["verify_blocks"],
            "fast_blocks": d["fast_blocks"],
            "fast_fallbacks": d["fast_fallbacks"],
        }
        return jnp.asarray(out, jnp.int32), stats

    def counters(self) -> Dict[str, int]:
        """Raw cumulative counters (metrics plane) — host-only, never blocks
        on the device, so schedulers can snapshot it around every session
        turn for per-request delta ledgers.  The fast path counts its hits
        in a per-session device accumulator that ``finish_session`` folds
        into ``layer_lookups``/``layer_hits`` (one readback per session, at
        retirement, off the decode path)."""
        return {
            "lookups": self.layer_lookups,
            "hits": self.layer_hits,
            "on_demand_loads": self.on_demand_loads,
            "prefetched": self.prefetcher.loaded_count,
            "evictions": self.cache.evictions,
            "prefetch_evicted_unused": self.cache.prefetch_evicted,
            "host_syncs": self.host_syncs,
            "verify_blocks": self.verify_blocks,
            "fast_blocks": self.fast_blocks,
            "fast_fallbacks": self.fast_fallbacks,
            "iterations": self.iterations,
            "drafted": self.drafted,
            "accepted": self.accepted,
            # resilience plane (chaos-hardened serving)
            "prefetch_errors": self.prefetcher.error_count,
            "prefetch_retries": self.prefetcher.retry_count,
            "checksum_failures": self.store.checksum_failures,
            "worker_restarts": self.prefetcher.worker_restarts,
            "degraded_rounds": self.degraded_rounds,
            "io_errors": self.io_errors,
        }

    def _draft_taps_for_moe(self, taps: Dict[str, jax.Array]) -> jax.Array:
        """Map draft-layer taps onto target MoE layers (layer-to-layer
        correspondence; Table 1 pairs share num_layers)."""
        stack = taps.get("layers")
        if stack is None:
            stack = list(taps.values())[0]
        n = self.store.num_layers
        off = self.cfg.first_dense_layers
        # draft layer (l + off) predicts target moe layer l
        if stack.shape[0] >= n + off:
            return stack[off:off + n]
        return stack[:n]

    def reset_stats(self):
        """Zero the cumulative counters (cache + prefetcher + engine) so a
        warmed engine can report clean steady-state numbers."""
        self.layer_hits = self.layer_lookups = 0
        self.on_demand_loads = self.host_syncs = 0
        self.verify_blocks = self.fast_blocks = self.fast_fallbacks = 0
        self.iterations = self.drafted = self.accepted = 0
        self.verify_rounds = self.round_launches = 0
        self.degraded_rounds = self.io_errors = 0
        self.store.checksum_failures = 0
        self.cache.reset_stats()
        self.prefetcher.reset_stats()

    def close(self):
        self.prefetcher.stop()
