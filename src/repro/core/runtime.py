"""SP-MoE offload-mode serving engine (paper-faithful runtime).

Combines every paper component end-to-end, for real, on whatever backend JAX
is running on:

  * speculative decoding (batch=1, greedy accept) — core/sd.py semantics;
  * target expert weights offloaded to a HostExpertStore; a fixed-slot
    ExpertCache with LRU lives on device;
  * drafting-stage cross-model prediction: draft gate-input taps × target
    gating networks -> prefetch tasks for layers 0..cutoff (Algorithm 1);
  * pipelined prefetching: async worker + batched I/O (Algorithm 2);
  * cached-first expert computation ordering (§4.3): the hit-experts' FFN is
    dispatched (asynchronously) while misses stream in, then the miss part is
    computed — compute/IO overlap without waiting on full availability.

Baseline policies (for the paper's comparisons) plug into the same loop:
  on-demand (Mixtral-Offloading), moe-infinity (historical top-k,
  request-level, depth-unbounded), adapmoe (same-model next-layer gating,
  synchronous prefetch).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import ExpertCache, ExpertKey
from repro.core.cutoff import CutoffDecision, HardwareProfile, solve_cutoff
from repro.core.offload import HostExpertStore
from repro.core.predictor import ExpertPredictor
from repro.core.prefetcher import Prefetcher
from repro.models import layers as L
from repro.models.moe import gate_topk, ffn_forward
from repro.models.transformer import DecoderLM

POLICIES = ("spmoe", "adapmoe", "moe-infinity", "on-demand")


class OffloadEngine:
    def __init__(self, cfg: ModelConfig, draft_cfg: ModelConfig,
                 tparams, dparams, *, cache_slots: int, draft_len: int = 4,
                 policy: str = "spmoe", cutoff: Optional[int] = None,
                 k_prefetch: Optional[int] = None,
                 prefetch_mode: str = "worker", batched_io: bool = True,
                 profile: Optional[HardwareProfile] = None,
                 max_seq: int = 512):
        assert policy in POLICIES
        assert cfg.is_moe, "offload engine targets MoE models"
        self.cfg, self.draft_cfg = cfg, draft_cfg
        self.policy = policy
        self.draft_len = draft_len
        self.max_seq = max_seq
        self.target = DecoderLM(cfg)
        self.draft = DecoderLM(draft_cfg)
        self.tparams, self.dparams = tparams, dparams
        self.store = HostExpertStore(cfg, tparams)
        self.cache = ExpertCache(cache_slots, self.store.buffer_shapes(),
                                 jnp.dtype(cfg.dtype))
        mode = prefetch_mode if policy in ("spmoe", "moe-infinity") else (
            "vanilla" if policy == "adapmoe" else "off")
        self.prefetcher = Prefetcher(self.store, self.cache, mode, batched_io)
        self.k = k_prefetch if k_prefetch is not None else cfg.num_experts_per_tok
        self.predictor = ExpertPredictor(cfg, tparams, self.k)
        # cutoff layer from the analytical model (or explicit override)
        if cutoff is not None:
            self.cutoff = cutoff
        elif profile is not None:
            self.cutoff = solve_cutoff(profile, self.k, self.store.num_layers,
                                       draft_len).cutoff_layer
        else:
            self.cutoff = self.store.num_layers - 1
        # MoE-Infinity history counts
        self.history = np.zeros((self.store.num_layers, cfg.num_experts))
        self._build_jitted()
        # stats
        self.layer_hits = 0
        self.layer_lookups = 0
        self.on_demand_loads = 0

    # ------------------------------------------------------------------ jit
    def _build_jitted(self):
        cfg = self.cfg
        num_slots = self.cache.num_slots

        def attn_half(lp, x, cache_l, pos):
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, cache_l = L.mla_decode(lp["attn"], h, cache_l, pos, cfg)
            else:
                a, cache_l = L.attention_decode(lp["attn"], h, cache_l, pos, cfg)
            x = x + a
            h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            return x, cache_l, h2

        def gate_fn(gate_w, h2):
            w, ids, probs, _ = gate_topk(gate_w, h2.reshape(-1, cfg.d_model),
                                         cfg.num_experts_per_tok)
            return w, ids, probs

        def cached_moe_apply(bufs, x, slot_ids, weights, choice_mask):
            """x: [T,d]; slot_ids/weights/choice_mask: [T,k] -> [T,d].
            Computes only choices where mask=1 (cached-first split)."""
            T, k = slot_ids.shape
            # masked choices are routed to the last real slot group (their
            # combine weight is zero) — an out-of-range overflow group would
            # leave ragged_dot rows uninitialized.
            flat = jnp.where(choice_mask.reshape(-1) > 0,
                             slot_ids.reshape(-1), num_slots - 1)
            order = jnp.argsort(flat)
            xs = x[order // k]
            gs = jnp.bincount(flat, length=num_slots)
            if "wg" in bufs:
                h = jax.nn.silu(jax.lax.ragged_dot(xs, bufs["wg"], gs))
                h = h * jax.lax.ragged_dot(xs, bufs["wu"], gs)
            else:
                h = jax.nn.gelu(jax.lax.ragged_dot(xs, bufs["wu"], gs))
            ys = jax.lax.ragged_dot(h, bufs["wd"], gs)
            w = (weights * choice_mask).reshape(-1)[order]
            return jnp.zeros_like(x).at[order // k].add(ys * w[:, None])

        def shared_and_residual(lp, x, h2, y_experts):
            if cfg.num_shared_experts:
                y_experts = y_experts + ffn_forward(lp["moe"]["shared"], h2, "swiglu")
            return x + y_experts

        def dense_block(lp, x, cache_l, pos):
            x, cache_l, h2 = attn_half(lp, x, cache_l, pos)
            y = ffn_forward(lp["ffn"], h2, cfg.ffn_activation)
            return x + y, cache_l

        def embed(tokens):
            return jnp.take(self.tparams["wte"], tokens, axis=0)

        def head(x):
            xf = L.rms_norm(x, self.tparams["ln_f"], cfg.norm_eps)
            if cfg.tie_embeddings:
                return jnp.einsum("bsd,vd->bsv", xf, self.tparams["wte"])
            return jnp.einsum("bsd,dv->bsv", xf, self.tparams["head"])

        self._attn_half = jax.jit(attn_half)
        self._gate = jax.jit(gate_fn)
        self._moe_apply = jax.jit(cached_moe_apply)
        self._shared_res = jax.jit(shared_and_residual)
        self._dense_block = jax.jit(dense_block)
        self._embed = jax.jit(embed)
        self._head = jax.jit(head)
        self._draft_step = jax.jit(functools.partial(
            self.draft.decode_step, collect_taps=True))

    # ------------------------------------------------------------- verification
    def _ensure_loaded(self, layer: int, ids: np.ndarray
                       ) -> Tuple[Dict[ExpertKey, int], List[ExpertKey]]:
        keys = [(layer, int(e)) for e in dict.fromkeys(ids.ravel().tolist())]
        hits, misses = self.cache.lookup(keys)
        self.layer_lookups += len(keys)
        self.layer_hits += len(hits)
        return hits, misses

    def _verify_block(self, tokens: jax.Array, pos: int, tcache):
        """Layer-wise target forward with cache-aware expert compute.
        tokens: [1, N+1]."""
        cfg = self.cfg
        x = self._embed(tokens)
        T = tokens.shape[1]
        kk = cfg.num_experts_per_tok
        # leading dense layers (deepseek)
        if "dense_layers" in self.tparams:
            for l in range(cfg.first_dense_layers):
                lp = jax.tree.map(lambda a: a[l], self.tparams["dense_layers"])
                cl = jax.tree.map(lambda a: a[l], tcache["dense_layers"])
                x, ncl = self._dense_block(lp, x, cl, pos)
                tcache["dense_layers"] = jax.tree.map(
                    lambda full, new, l=l: full.at[l].set(new),
                    tcache["dense_layers"], ncl)
        moe_params = self.tparams["layers"]
        for l in range(self.store.num_layers):
            lp = jax.tree.map(lambda a: a[l], moe_params)
            cl = jax.tree.map(lambda a: a[l], tcache["layers"])
            x, ncl, h2 = self._attn_half(lp, x, cl, pos)
            tcache["layers"] = jax.tree.map(
                lambda full, new, l=l: full.at[l].set(new), tcache["layers"], ncl)
            w, ids, probs = self._gate(lp["moe"]["gate"], h2)
            ids_np = np.asarray(ids)
            self.history[l][np.unique(ids_np)] += 1
            # AdapMoE baseline: predict next layer from *this* layer's gate
            # input using the target's own gates, synchronous prefetch.
            if self.policy == "adapmoe" and l + 1 < self.store.num_layers:
                nxt = self.predictor.predict_layer(l + 1, h2[:, -1:])
                _, miss = self.cache.lookup(nxt, touch=False)
                if miss:
                    self.prefetcher.submit(miss)     # vanilla mode: blocking
            hits, misses = self._ensure_loaded(l, ids_np)
            hit_set = set(hits.keys())
            hit_mask = np.isin(ids_np, [e for (_, e) in hit_set]).astype(np.float32)
            # cached-first compute (dispatches async under jax)
            slot_lut = np.zeros((cfg.num_experts,), np.int64)
            for (_, e), s in hits.items():
                slot_lut[e] = s
            xf = h2.reshape(T, cfg.d_model)
            y = self._moe_apply(self.cache.bufs, xf,
                                jnp.asarray(slot_lut[ids_np], jnp.int32),
                                w, jnp.asarray(hit_mask))
            if misses:
                # on-demand batched loads, in cache-capacity-bounded waves:
                # each wave's experts are loaded (evicting as needed — the
                # hit experts' compute is already dispatched) and its share
                # of the block is computed before the next wave streams in.
                self.on_demand_loads += len(misses)
                wave_size = max(1, self.cache.num_slots)
                for w0 in range(0, len(misses), wave_size):
                    wave = misses[w0:w0 + wave_size]
                    arrays = self.store.fetch(wave)
                    slots = self.cache.insert(wave, arrays, mark_used=True)
                    for (key, s) in zip(wave, slots):
                        slot_lut[key[1]] = s
                    wave_experts = [e for (_, e) in wave]
                    wave_mask = np.isin(ids_np, wave_experts).astype(np.float32)
                    y = y + self._moe_apply(
                        self.cache.bufs, xf,
                        jnp.asarray(slot_lut[ids_np], jnp.int32),
                        w, jnp.asarray(wave_mask))
            x = self._shared_res(lp, x, h2, y.reshape(1, T, cfg.d_model))
        return self._head(x), tcache

    # ---------------------------------------------------------------- generate
    def generate(self, prompt: jax.Array, max_new_tokens: int
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
        assert prompt.shape[0] == 1
        cfg = self.cfg
        N = self.draft_len
        t0 = time.perf_counter()
        # prefill: run target through the cache-aware path too (loads warm it)
        _, dcache = self.draft.prefill(self.dparams, prompt, self.max_seq)
        tcache = self.target.init_cache(1, self.max_seq)
        logits, tcache = self._verify_block(prompt, 0, tcache)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        pos = prompt.shape[1]
        out = [int(cur[0, 0])]
        iters = accepted = 0
        while len(out) < max_new_tokens:
            # MoE-Infinity: request-level historical prefetch, all layers
            if self.policy == "moe-infinity":
                for l in range(self.store.num_layers):
                    top = np.argsort(-self.history[l])[: self.k]
                    keys = [(l, int(e)) for e in top]
                    _, miss = self.cache.lookup(keys, touch=False)
                    if miss:
                        self.prefetcher.submit(miss)
            # ---- drafting stage (+ SP-MoE speculative prefetching) ----
            drafts = []
            tok = cur
            for i in range(N):
                lg, dcache, taps = self._draft_step(self.dparams, dcache, tok,
                                                    jnp.int32(pos + i))
                tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                drafts.append(int(tok[0, 0]))
                if self.policy == "spmoe" and self.cutoff >= 0:
                    tap_stack = self._draft_taps_for_moe(taps)
                    for l in range(min(self.cutoff + 1, self.store.num_layers)):
                        keys = self.predictor.predict_layer(l, tap_stack[l])
                        _, miss = self.cache.lookup(keys, touch=False)
                        if miss:
                            self.prefetcher.submit(miss)
            # ---- verification ----
            block = jnp.concatenate(
                [cur, jnp.asarray([drafts], jnp.int32)], axis=1)
            tlogits, tcache = self._verify_block(block, pos, tcache)
            greedy = np.asarray(jnp.argmax(tlogits, -1))[0]
            d = np.asarray(drafts)
            match = d == greedy[:N]
            n_acc = int(np.cumprod(match.astype(np.int64)).sum())
            emitted = [int(t) for t in d[:n_acc]] + [int(greedy[n_acc])]
            out.extend(emitted)
            cur = jnp.asarray([[int(greedy[n_acc])]], jnp.int32)
            pos += n_acc + 1
            iters += 1
            accepted += n_acc
        self.prefetcher.drain()
        dt = time.perf_counter() - t0
        stats = {
            "wall_s": dt,
            "tpot_wall": dt / max(len(out), 1),
            "iterations": iters,
            "acceptance_rate": accepted / max(iters * N, 1),
            "hit_rate": self.layer_hits / max(self.layer_lookups, 1),
            "on_demand_loads": self.on_demand_loads,
            "prefetched": self.prefetcher.loaded_count,
            "evictions": self.cache.evictions,
            "prefetch_evicted_unused": self.cache.prefetch_evicted,
            "cutoff_layer": self.cutoff,
        }
        return jnp.asarray(out[:max_new_tokens], jnp.int32), stats

    def _draft_taps_for_moe(self, taps: Dict[str, jax.Array]) -> jax.Array:
        """Map draft-layer taps onto target MoE layers (layer-to-layer
        correspondence; Table 1 pairs share num_layers)."""
        stack = taps.get("layers")
        if stack is None:
            stack = list(taps.values())[0]
        n = self.store.num_layers
        off = self.cfg.first_dense_layers
        # draft layer (l + off) predicts target moe layer l
        if stack.shape[0] >= n + off:
            return stack[off:off + n]
        return stack[:n]

    def close(self):
        self.prefetcher.stop()
