"""Host-resident expert store (the offloaded side of the cache).

All expert weights stay in host memory for the lifetime of the engine —
eviction never copies back (paper §7).  ``fetch`` performs the batched read
into **preallocated, contiguous staging buffers** (the pinned-memory analogue
on this backend): one ``np.take(..., out=...)`` per weight tensor, no
per-call allocation, no fancy-indexed temporary.  Per *thread*, two staging
buffers alternate (double buffering) so the H2D transfer dispatched by
``ExpertCache.insert`` on batch *i* overlaps the host gather of batch *i+1*
— the prefetch worker's pipeline never stalls on its own staging memory.

The staging ring is **thread-local**: the prefetch worker and the compute
loop both call ``fetch`` concurrently (worker prefetch vs. the slow path's
miss waves), and a shared ring would let one thread's gather overwrite the
other's staged weights before the device copy happens.

Payload integrity: the canonical host arrays are the ground truth, and
every (layer, expert) has a lazily-memoized CRC32 over its weight tensors.
``fetch_verified`` re-checksums the *staged* copy against the canonical
sum and raises :class:`~repro.core.chaos.PayloadCorruption` on mismatch —
a corrupted transfer (chaos-injected or real) is quarantined in staging
and never reaches the device cache; the caller's retry loop refetches.
An optional :class:`~repro.core.chaos.ChaosInjector` makes ``fetch``
fallible on purpose (transient errors, latency spikes, staged-byte
corruption) for resilience tests and the ``--mode chaos`` benchmark.
"""
from __future__ import annotations

import threading
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import ExpertKey
from repro.core.chaos import ChaosInjector, PayloadCorruption

_NUM_STAGING = 2          # double buffer: gather batch i+1 while i transfers


class HostExpertStore:
    """Extracts per-(layer, expert) weights from a target model's params and
    keeps them as host numpy arrays."""

    def __init__(self, cfg: ModelConfig, params, staging_batch: int = 16,
                 chaos: Optional[ChaosInjector] = None):
        assert cfg.is_moe, "HostExpertStore requires an MoE config"
        self.cfg = cfg
        moe = params["layers"]["moe"]        # stacked [L_moe, E, ...]
        self.names = [n for n in ("wg", "wu", "wd") if n in moe]
        self._store = {n: np.ascontiguousarray(moe[n]) for n in self.names}
        self.num_layers = self._store[self.names[0]].shape[0]
        self.num_experts = self._store[self.names[0]].shape[1]
        # flat [L*E, ...] views for single-gather batched reads
        self._flat = {n: self._store[n].reshape(
            (self.num_layers * self.num_experts,) + self._store[n].shape[2:])
            for n in self.names}
        # preallocated staging rings, one per calling thread (grown on
        # demand, never shrunk)
        self._stage_batch = max(1, staging_batch)
        self._tls = threading.local()
        self.chaos = chaos
        self.checksum_failures = 0         # staged payloads that failed CRC
        self._sums: Dict[ExpertKey, int] = {}   # canonical CRC32 per key
        self._sums_lock = threading.Lock()

    def _alloc_stage(self, cap: int) -> Dict[str, np.ndarray]:
        return {n: np.empty((cap,) + self._store[n].shape[2:],
                            self._store[n].dtype) for n in self.names}

    def _thread_ring(self, min_cap: int):
        tls = self._tls
        if getattr(tls, "stages", None) is None or tls.cap < min_cap:
            tls.cap = max(self._stage_batch, min_cap)
            tls.stages = [self._alloc_stage(tls.cap)
                          for _ in range(_NUM_STAGING)]
            tls.i = 0
        return tls

    def buffer_shapes(self) -> Dict[str, tuple]:
        return {n: self._store[n].shape[2:] for n in self.names}

    def expert_bytes(self) -> int:
        return int(sum(self._store[n][0, 0].nbytes for n in self.names))

    def fetch(self, keys: Sequence[ExpertKey]) -> Dict[str, np.ndarray]:
        """Batched host read: name -> [len(keys), ...] staged contiguously.

        The returned arrays are views into the calling thread's current
        staging buffer; they stay valid until that thread's next-but-one
        ``fetch`` (double buffering) — long enough for
        ``ExpertCache.insert`` to dispatch the H2D transfer.
        """
        if self.chaos is not None:
            self.chaos.on_fetch(len(keys))     # may spike (sleep) or raise
        n_keys = len(keys)
        tls = self._thread_ring(n_keys)
        stage = tls.stages[tls.i]
        tls.i = (tls.i + 1) % _NUM_STAGING
        idx = np.fromiter((l * self.num_experts + e for (l, e) in keys),
                          np.int64, count=n_keys)
        out = {}
        for n in self.names:
            np.take(self._flat[n], idx, axis=0, out=stage[n][:n_keys])
            out[n] = stage[n][:n_keys]
        if self.chaos is not None:
            self.chaos.maybe_corrupt(out)      # poisons the STAGED copy only
        return out

    # ------------------------------------------------------------- integrity
    def expected_checksum(self, key: ExpertKey) -> int:
        """Canonical CRC32 of one expert's weight tensors (memoized — the
        host store is immutable for the engine's lifetime)."""
        with self._sums_lock:
            s = self._sums.get(key)
        if s is None:
            i = key[0] * self.num_experts + key[1]
            s = 0
            for n in self.names:
                s = zlib.crc32(self._flat[n][i].tobytes(), s)
            with self._sums_lock:
                self._sums[key] = s
        return s

    def payload_checksum(self, arrays: Dict[str, np.ndarray], i: int) -> int:
        """CRC32 of row ``i`` of a fetched batch, in canonical name order."""
        s = 0
        for n in self.names:
            s = zlib.crc32(np.ascontiguousarray(arrays[n][i]).tobytes(), s)
        return s

    def verify_payload(self, keys: Sequence[ExpertKey],
                       arrays: Dict[str, np.ndarray]) -> List[int]:
        """Indices of fetched rows whose staged bytes do not match the
        canonical checksum (empty = clean batch)."""
        return [i for i, k in enumerate(keys)
                if self.payload_checksum(arrays, i) != self.expected_checksum(k)]

    def fetch_verified(self, keys: Sequence[ExpertKey]
                       ) -> Dict[str, np.ndarray]:
        """``fetch`` + checksum verification: a corrupted staged payload is
        quarantined (never returned for insertion) by raising
        :class:`PayloadCorruption` — the caller's retry loop refetches."""
        arrays = self.fetch(keys)
        bad = self.verify_payload(keys, arrays)
        if bad:
            self.checksum_failures += len(bad)
            raise PayloadCorruption(
                f"checksum mismatch on fetched experts "
                f"{[tuple(keys[i]) for i in bad]}")
        return arrays

    def strip_experts(self, params):
        """Return params with expert tensors removed (host-only now) — the
        resident footprint the offload engine actually keeps on device.

        Copies every dict on the path to ``params["layers"]["moe"]``
        explicitly so the caller's nested params are never mutated (a
        ``jax.tree.map`` identity copy is an implementation detail of the
        pytree registry, not a documented isolation guarantee).
        """
        import jax.numpy as jnp
        out = dict(params)
        out["layers"] = dict(params["layers"])
        out["layers"]["moe"] = dict(params["layers"]["moe"])
        for n in self.names:
            out["layers"]["moe"][n] = jnp.zeros((0,), jnp.bfloat16)
        return out
