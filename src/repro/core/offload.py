"""Host-resident expert store (the offloaded side of the cache).

All expert weights stay in host memory for the lifetime of the engine —
eviction never copies back (paper §7).  ``fetch`` performs the batched read:
one contiguous ``np.stack`` per weight tensor, which the ExpertCache turns
into a single device transfer.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import ExpertKey


class HostExpertStore:
    """Extracts per-(layer, expert) weights from a target model's params and
    keeps them as host numpy arrays."""

    def __init__(self, cfg: ModelConfig, params):
        assert cfg.is_moe, "HostExpertStore requires an MoE config"
        self.cfg = cfg
        moe = params["layers"]["moe"]        # stacked [L_moe, E, ...]
        self.names = [n for n in ("wg", "wu", "wd") if n in moe]
        self._store = {n: np.asarray(moe[n]) for n in self.names}
        self.num_layers = self._store[self.names[0]].shape[0]
        self.num_experts = self._store[self.names[0]].shape[1]

    def buffer_shapes(self) -> Dict[str, tuple]:
        return {n: self._store[n].shape[2:] for n in self.names}

    def expert_bytes(self) -> int:
        return int(sum(self._store[n][0, 0].nbytes for n in self.names))

    def fetch(self, keys: Sequence[ExpertKey]) -> Dict[str, np.ndarray]:
        """Batched host read: name -> [len(keys), ...]."""
        ls = [k[0] for k in keys]
        es = [k[1] for k in keys]
        return {n: self._store[n][ls, es] for n in self.names}

    def strip_experts(self, params):
        """Return params with expert tensors removed (host-only now) — the
        resident footprint the offload engine actually keeps on device."""
        import jax.numpy as jnp
        out = jax.tree.map(lambda x: x, params)  # shallow-ish copy
        for n in self.names:
            out["layers"]["moe"][n] = jnp.zeros((0,), jnp.bfloat16)
        return out
