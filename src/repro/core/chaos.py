"""Fault injection for the serving I/O plane (chaos harness).

The prefetch/offload stack assumes host I/O never fails; this module makes
it fail **on purpose, deterministically**, so the resilience layer can be
exercised in tests and benchmarks the way ``runtime.fault_tolerance``'s
``FailureInjector`` exercises the training supervisor.  A single seeded
:class:`ChaosInjector` is shared by the :class:`~repro.core.offload.
HostExpertStore`, the :class:`~repro.core.cache.ExpertCache` and the
:class:`~repro.core.prefetcher.Prefetcher` and injects four fault classes:

* **transient fetch errors** — ``HostExpertStore.fetch`` raises
  :class:`ChaosError` before touching the staging buffers;
* **latency spikes** — ``fetch`` sleeps ``spike_s`` before returning
  (models a contended PCIe link / an overloaded host);
* **payload corruption** — bytes of the *staged* copy are flipped after the
  gather (the canonical host store is never touched), caught by the
  checksum verification in ``fetch_verified`` / the prefetcher;
* **worker death** — the prefetch worker thread exits on every Nth task
  (the task is handed back to the queue first, so in-flight accounting
  survives; the supervisor restarts the worker).

Determinism: draws come from one seeded ``np.random.Generator`` behind a
lock, so a given seed produces the same fault schedule for the same
sequence of I/O calls.  Two hard bounds make injected faults *survivable by
construction* — losslessness under chaos is a guarantee, not luck:

* ``max_consecutive_faults`` caps back-to-back hard faults, so a bounded
  retry budget can always out-wait an unlucky streak;
* :meth:`ChaosInjector.calm` is a thread-local suppression scope the
  decode-critical retry loop (``OffloadEngine._load_wave``) enters on its
  FINAL attempt: injected faults never exhaust the on-demand path's retry
  budget.  Real (non-injected) failures are unaffected and still surface
  as :class:`ExpertLoadError` → ``finish_reason="io_error"``.

The error taxonomy lives here (not in the prefetcher) because both the
engine facade and the runtime need it without importing each other:

* :class:`ChaosError` — an injected transient I/O fault (an ``IOError``,
  so generic transient-retry handlers cover it);
* :class:`PayloadCorruption` — checksum mismatch on a fetched payload;
* :class:`ExpertLoadError` — the final rung of the degradation ladder:
  an expert could not be loaded even synchronously within the retry
  budget; the owning request finishes with ``finish_reason="io_error"``
  (tokens are never wrong — the request just ends).
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


class ChaosError(IOError):
    """An injected transient I/O fault (fetch or insert)."""


class PayloadCorruption(ChaosError):
    """A fetched expert payload failed checksum verification."""


class ExpertLoadError(RuntimeError):
    """An expert could not be loaded even synchronously (retry budget
    exhausted on the on-demand path) — the request finishes with
    ``finish_reason="io_error"`` instead of emitting wrong tokens."""


@dataclass
class ChaosConfig:
    """Seeded fault schedule for the serving I/O plane.  All rates are
    per-call probabilities in [0, 1]; everything defaults to off."""
    seed: int = 0
    fetch_error_rate: float = 0.0     # ChaosError raised from store.fetch
    insert_error_rate: float = 0.0    # ChaosError raised entering cache.insert
    spike_rate: float = 0.0           # latency spike on fetch
    spike_s: float = 0.01             # spike duration (seconds)
    corrupt_rate: float = 0.0         # flip staged payload bytes after fetch
    kill_worker_every: int = 0        # crash the worker on every Nth task (0=never)
    max_consecutive_faults: int = 2   # hard-fault streak bound (see module doc)

    @property
    def enabled(self) -> bool:
        return (self.fetch_error_rate > 0 or self.insert_error_rate > 0
                or self.spike_rate > 0 or self.corrupt_rate > 0
                or self.kill_worker_every > 0)


class ChaosInjector:
    """Deterministic, thread-safe fault source.  One instance is shared by
    the store, the cache and the prefetcher of a chaos-enabled engine; the
    ``injected`` dict is the ground truth tests compare detection counters
    against."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self._lock = threading.Lock()
        self._consecutive = 0          # back-to-back hard faults (bounded)
        self._tasks_seen = 0           # worker-kill schedule position
        self._calm = threading.local() # per-thread suppression depth
        self.injected: Dict[str, int] = {
            "fetch_errors": 0, "insert_errors": 0, "spikes": 0,
            "corruptions": 0, "worker_kills": 0}

    # ------------------------------------------------------------- suppression
    @contextmanager
    def calm(self):
        """Suppress injection on the calling thread (decode-critical final
        attempts).  Reentrant; only injected faults are suppressed."""
        depth = getattr(self._calm, "depth", 0)
        self._calm.depth = depth + 1
        try:
            yield
        finally:
            self._calm.depth = depth

    def _suppressed(self) -> bool:
        return getattr(self._calm, "depth", 0) > 0

    def _hard_fault(self, rate: float) -> bool:
        """One locked draw for a hard (retry-consuming) fault, honouring the
        consecutive-streak bound.  Resets the streak on a clean draw; a
        zero-rate class is NEUTRAL (no draw, no reset) — otherwise a
        disabled fault class would wipe the streak another class just set,
        and the bound would stop bounding."""
        if rate <= 0:
            return False
        with self._lock:
            if self._consecutive < self.cfg.max_consecutive_faults \
                    and self._rng.random() < rate:
                self._consecutive += 1
                return True
            self._consecutive = 0
            return False

    # --------------------------------------------------------------- injection
    def on_fetch(self, n_keys: int) -> None:
        """Called at ``HostExpertStore.fetch`` entry: may sleep (spike) and
        may raise :class:`ChaosError` (transient read failure)."""
        if self._suppressed():
            return
        if self.cfg.spike_rate > 0:
            with self._lock:
                spike = self._rng.random() < self.cfg.spike_rate
            if spike:
                self.injected["spikes"] += 1
                time.sleep(self.cfg.spike_s)      # sleep outside the lock
        if self._hard_fault(self.cfg.fetch_error_rate):
            self.injected["fetch_errors"] += 1
            raise ChaosError(f"injected transient fetch error ({n_keys} keys)")

    def maybe_corrupt(self, arrays: Dict[str, np.ndarray]) -> bool:
        """Called after the staging gather: flip one byte of the first staged
        row (the canonical host store is untouched — only this fetch's copy
        is poisoned, which is exactly what checksum verification must
        catch).  Returns True when a corruption was injected."""
        if self._suppressed() or not arrays:
            return False
        if not self._hard_fault(self.cfg.corrupt_rate):
            return False
        first = next(iter(arrays.values()))
        first[:1].view(np.uint8).reshape(-1)[0] ^= 0xFF
        self.injected["corruptions"] += 1
        return True

    def on_insert(self, n_keys: int) -> None:
        """Called at ``ExpertCache.insert`` entry, BEFORE any bookkeeping
        mutates — a failed insert must leave the cache untouched."""
        if self._suppressed():
            return
        if self._hard_fault(self.cfg.insert_error_rate):
            self.injected["insert_errors"] += 1
            raise ChaosError(f"injected transient insert error ({n_keys} keys)")

    def should_kill_worker(self) -> bool:
        """Deterministic worker-death schedule: True on every Nth prefetch
        task the worker dequeues (never suppressed by ``calm`` — worker
        death is survivable by supervision, not by retries)."""
        if self.cfg.kill_worker_every <= 0:
            return False
        with self._lock:
            self._tasks_seen += 1
            kill = self._tasks_seen % self.cfg.kill_worker_every == 0
        if kill:
            self.injected["worker_kills"] += 1
        return kill
