"""Device-side expert cache with LRU eviction (paper §3.3 / §4.4).

The cache is a fixed pool of ``slots`` expert-weight buffers resident in
device memory (HBM), plus host-side bookkeeping:

* ``table``       ExpertKey -> slot (the page table)
* ``lru``         access order (OrderedDict; head = eviction candidate)

and — when constructed with ``table_shape=(L, E)`` — a **device-resident
mirror of the page table**, ``table_dev [L, E] -> slot | -1``, maintained
incrementally (one fused int32 scatter per insert covering both the evicted
keys and the fresh ones).  The offload runtime's verification hot path reads
it with a plain device gather, so routing-to-slot translation never touches
the host (see runtime._verify_block).

Slot buffers are updated with donated jitted scatters so the pool is updated
in place — no reallocation, no copy-back to host on eviction (§7: classic
space-time tradeoff, experts always stay host-resident).

Concurrency contract: the prefetch worker and the compute loop both mutate
the cache.  All bookkeeping is under ``self.lock``; because inserts *donate*
``bufs``/``table_dev`` (invalidating the old jax handles), any reader that
dispatches compute against them must snapshot them under the same lock
(``snapshot()``) so a concurrent insert can't delete the handle between read
and dispatch.  In-flight device computation is safe either way — XLA
sequences buffer donation after pending consumers.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ExpertKey = Tuple[int, int]   # (layer, expert)


def _batched_insert(bufs, stacked, slots):
    """bufs: dict name -> [slots, ...]; stacked: dict name -> [n, ...]."""
    return {name: bufs[name].at[slots].set(stacked[name]) for name in bufs}


def _table_scatter(table, ls, es, vals):
    """table: [L, E] int32; point-scatter of slot ids (or -1 tombstones)."""
    return table.at[ls, es].set(vals)


class ExpertCache:
    """LRU cache of expert weights in device memory.

    Thread-safe: the prefetch worker and the compute loop both mutate it.
    """

    def __init__(self, num_slots: int, buffer_shapes: Dict[str, tuple],
                 dtype=jnp.bfloat16,
                 table_shape: Optional[Tuple[int, int]] = None,
                 chaos=None):
        self.num_slots = num_slots
        self.dtype = dtype
        # optional fault injector (core/chaos.py): inserts may raise an
        # injected transient error BEFORE any bookkeeping mutates
        self.chaos = chaos
        self.bufs = {name: jnp.zeros((num_slots,) + tuple(shape), dtype)
                     for name, shape in buffer_shapes.items()}
        self.table: Dict[ExpertKey, int] = {}
        self.lru: "OrderedDict[ExpertKey, int]" = OrderedDict()
        self.free: List[int] = list(range(num_slots))
        self.lock = threading.RLock()
        self._insert = jax.jit(_batched_insert, donate_argnums=(0,))
        # device-resident page-table mirror [L, E] -> slot | -1
        self.table_shape = table_shape
        self.table_dev: Optional[jax.Array] = (
            jnp.full(table_shape, -1, jnp.int32)
            if table_shape is not None else None)
        # scatter lengths are padded to powers of two (repeating the final
        # entry — a duplicate set of the same value is deterministic), so the
        # jitted scatter compiles one executable per bucket instead of one
        # per distinct insert size; the trace counter is the regression hook
        self.table_scatter_traces = 0

        def _counting_scatter(table, ls, es, vals):
            self.table_scatter_traces += 1     # trace-time side effect only
            return _table_scatter(table, ls, es, vals)

        self._scatter_table = jax.jit(_counting_scatter, donate_argnums=(0,))
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.prefetch_evicted = 0   # evicted before ever being used

    # ------------------------------------------------------------------ reads
    def contains(self, key: ExpertKey) -> bool:
        with self.lock:
            return key in self.table

    def lookup(self, keys: Sequence[ExpertKey], touch: bool = True
               ) -> Tuple[Dict[ExpertKey, int], List[ExpertKey]]:
        """Split into (hits: key->slot, misses).  Updates LRU + stats."""
        with self.lock:
            hits, misses = {}, []
            for k in keys:
                if k in self.table:
                    hits[k] = self.table[k]
                    self.hits += 1
                    if touch:
                        self.lru.move_to_end(k)
                        self.lru[k] = 1    # mark used
                else:
                    misses.append(k)
                    self.misses += 1
            return hits, misses

    def slots_of(self, keys: Sequence[ExpertKey]) -> jnp.ndarray:
        with self.lock:
            return jnp.array([self.table[k] for k in keys], jnp.int32)

    def snapshot(self) -> Tuple[Dict[str, jax.Array], Optional[jax.Array]]:
        """(bufs, table_dev) captured atomically w.r.t. donating inserts.

        Dispatch device compute against the snapshot while still holding
        ``self.lock`` (dispatch is enqueue-only, so the critical section is
        short); once dispatched, a concurrent donation is sequenced by the
        runtime after the in-flight consumers.
        """
        with self.lock:
            return self.bufs, self.table_dev

    # ----------------------------------------------------------------- writes
    def _allocate(self, n: int, protect: frozenset = frozenset()
                  ) -> Tuple[List[int], List[ExpertKey]]:
        """Reserve n slots, evicting LRU entries as needed.  Lock held.
        Keys in ``protect`` (the insert batch's already-present members) are
        never chosen as victims — evicting them would invalidate the slots
        this very insert is about to return.  Returns (slots, evicted)."""
        slots: List[int] = []
        evicted: List[ExpertKey] = []
        while len(slots) < n:
            if self.free:
                slots.append(self.free.pop())
                continue
            victim = next((k for k in self.lru if k not in protect), None)
            if victim is None:
                raise ValueError(
                    f"batch needs {n} slots but cache capacity is "
                    f"{self.num_slots}; load in waves "
                    f"(see runtime._verify_block)")
            used = self.lru.pop(victim)
            slots.append(self.table.pop(victim))
            evicted.append(victim)
            self.evictions += 1
            if not used:
                self.prefetch_evicted += 1
        return slots, evicted

    def insert(self, keys: Sequence[ExpertKey],
               host_arrays: Dict[str, np.ndarray],
               mark_used: bool = False,
               stats: Optional[Dict[str, int]] = None) -> List[int]:
        """Batched I/O (paper §3.3): one device transfer + one donated scatter
        for the whole group of experts.  host_arrays: name -> [n, ...].

        Asynchronous by construction: the H2D transfer and both scatters are
        dispatched, not awaited — the caller's next consumer of ``bufs`` /
        ``table_dev`` is sequenced after them by the jax runtime, so the
        prefetch worker returns immediately and its H2D overlaps whatever the
        host does next (the next ``HostExpertStore.fetch`` in particular —
        that is the double-buffering contract, see offload.py).  Use
        ``wait()`` for a hard barrier.

        ``stats`` (optional) is credited with this call's ``evictions`` /
        ``prefetch_evicted_unused`` — how per-session I/O ledgers attribute
        eviction work to the session (or prefetch task) that caused it
        instead of to whoever's turn the async load happened to land in.
        """
        if not keys:
            return []
        if self.chaos is not None:
            # injected transient insert failure, raised before the lock and
            # before ANY bookkeeping — a failed insert leaves the cache
            # exactly as it was, so the caller's retry is safe
            self.chaos.on_insert(len(keys))
        with self.lock:
            if len(set(keys)) > self.num_slots:
                raise ValueError(
                    f"batch of {len(set(keys))} experts exceeds cache "
                    f"capacity {self.num_slots}; load in waves "
                    f"(see runtime._verify_block)")
            # dedupe (first occurrence wins) — a duplicated key must not
            # allocate two slots, that would leak one permanently
            seen = set()
            fresh: List[ExpertKey] = []
            sel: List[int] = []
            for i, k in enumerate(keys):
                if k not in self.table and k not in seen:
                    fresh.append(k)
                    sel.append(i)
                    seen.add(k)
            if fresh:
                ev0, pu0 = self.evictions, self.prefetch_evicted
                slots, evicted = self._allocate(
                    len(fresh), protect=frozenset(keys))
                if stats is not None:        # lock held: counters consistent
                    stats["evictions"] = stats.get("evictions", 0) + \
                        self.evictions - ev0
                    stats["prefetch_evicted_unused"] = \
                        stats.get("prefetch_evicted_unused", 0) + \
                        self.prefetch_evicted - pu0
                if len(sel) == len(host_arrays[next(iter(host_arrays))]):
                    picked = {n: arr for n, arr in host_arrays.items()}
                else:
                    picked = {n: arr[sel] for n, arr in host_arrays.items()}
                stacked = {n: jax.device_put(np.asarray(arr, self.dtype))
                           for n, arr in picked.items()}
                slot_arr = jnp.array(slots, jnp.int32)
                self.bufs = self._insert(self.bufs, stacked, slot_arr)
                for k, s in zip(fresh, slots):
                    self.table[k] = s
                    self.lru[k] = 1 if mark_used else 0
                    self.lru.move_to_end(k)
                if self.table_dev is not None:
                    ls = np.fromiter((k[0] for k in evicted + fresh), np.int32)
                    es = np.fromiter((k[1] for k in evicted + fresh), np.int32)
                    vals = np.asarray([-1] * len(evicted) + slots, np.int32)
                    # pad to the next power of two by repeating the final
                    # (l, e, val) triple — same index, same value, so the
                    # duplicate set is a deterministic no-op
                    pad = (1 << (len(vals) - 1).bit_length()) - len(vals)
                    if pad:
                        ls = np.concatenate([ls, np.repeat(ls[-1:], pad)])
                        es = np.concatenate([es, np.repeat(es[-1:], pad)])
                        vals = np.concatenate([vals, np.repeat(vals[-1:], pad)])
                    self.table_dev = self._scatter_table(
                        self.table_dev, ls, es, vals)
            # refresh LRU position of already-present keys
            for k in keys:
                if k in self.lru:
                    self.lru.move_to_end(k)
            return [self.table[k] for k in keys]

    # back-compat alias: insert() is already non-blocking; the name documents
    # intent at prefetcher call sites.
    insert_async = insert

    def wait(self):
        """Barrier: ensure all in-flight buffer updates are materialized."""
        with self.lock:
            leaves = jax.tree.leaves(self.bufs)
            if self.table_dev is not None:
                leaves = leaves + [self.table_dev]
        jax.block_until_ready(leaves)

    # ------------------------------------------------------------------ stats
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def reset_stats(self):
        with self.lock:
            self.hits = self.misses = self.evictions = self.prefetch_evicted = 0

    def check_invariants(self) -> bool:
        """Property-test hook: page table and LRU agree, no slot aliasing,
        and the device table mirror matches the host page table exactly."""
        with self.lock:
            if set(self.table.keys()) != set(self.lru.keys()):
                return False
            slots = list(self.table.values())
            if len(slots) != len(set(slots)):
                return False
            if any(s < 0 or s >= self.num_slots for s in slots):
                return False
            if set(slots) & set(self.free):
                return False
            if len(slots) + len(self.free) != self.num_slots:
                return False
            if self.table_dev is not None:
                tdev = np.asarray(self.table_dev)
                want = np.full(self.table_shape, -1, np.int32)
                for (l, e), s in self.table.items():
                    want[l, e] = s
                if not np.array_equal(tdev, want):
                    return False
            return True
