"""Device-side expert cache with LRU eviction (paper §3.3 / §4.4).

The cache is a fixed pool of ``slots`` expert-weight buffers resident in
device memory (HBM), plus host-side bookkeeping:

* ``table``   ExpertKey -> slot (the page table)
* ``lru``     access order (OrderedDict; head = eviction candidate)

Slot buffers are updated with donated jitted scatters so the pool is updated
in place — no reallocation, no copy-back to host on eviction (§7: classic
space-time tradeoff, experts always stay host-resident).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ExpertKey = Tuple[int, int]   # (layer, expert)


def _batched_insert(bufs, stacked, slots):
    """bufs: dict name -> [slots, ...]; stacked: dict name -> [n, ...]."""
    return {name: bufs[name].at[slots].set(stacked[name]) for name in bufs}


class ExpertCache:
    """LRU cache of expert weights in device memory.

    Thread-safe: the prefetch worker and the compute loop both mutate it.
    """

    def __init__(self, num_slots: int, buffer_shapes: Dict[str, tuple],
                 dtype=jnp.bfloat16):
        self.num_slots = num_slots
        self.dtype = dtype
        self.bufs = {name: jnp.zeros((num_slots,) + tuple(shape), dtype)
                     for name, shape in buffer_shapes.items()}
        self.table: Dict[ExpertKey, int] = {}
        self.lru: "OrderedDict[ExpertKey, int]" = OrderedDict()
        self.free: List[int] = list(range(num_slots))
        self.lock = threading.RLock()
        self._insert = jax.jit(_batched_insert, donate_argnums=(0,))
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.prefetch_evicted = 0   # evicted before ever being used

    # ------------------------------------------------------------------ reads
    def contains(self, key: ExpertKey) -> bool:
        with self.lock:
            return key in self.table

    def lookup(self, keys: Sequence[ExpertKey], touch: bool = True
               ) -> Tuple[Dict[ExpertKey, int], List[ExpertKey]]:
        """Split into (hits: key->slot, misses).  Updates LRU + stats."""
        with self.lock:
            hits, misses = {}, []
            for k in keys:
                if k in self.table:
                    hits[k] = self.table[k]
                    self.hits += 1
                    if touch:
                        self.lru.move_to_end(k)
                        self.lru[k] = 1    # mark used
                else:
                    misses.append(k)
                    self.misses += 1
            return hits, misses

    def slots_of(self, keys: Sequence[ExpertKey]) -> jnp.ndarray:
        with self.lock:
            return jnp.array([self.table[k] for k in keys], jnp.int32)

    # ----------------------------------------------------------------- writes
    def _allocate(self, n: int) -> List[int]:
        """Reserve n slots, evicting LRU entries as needed.  Lock held."""
        if n > self.num_slots:
            raise ValueError(
                f"batch of {n} experts exceeds cache capacity "
                f"{self.num_slots}; load in waves (see runtime._verify_block)")
        slots = []
        while len(slots) < n:
            if self.free:
                slots.append(self.free.pop())
                continue
            victim, used = self.lru.popitem(last=False)
            slots.append(self.table.pop(victim))
            self.evictions += 1
            if not used:
                self.prefetch_evicted += 1
        return slots

    def insert(self, keys: Sequence[ExpertKey],
               host_arrays: Dict[str, np.ndarray],
               mark_used: bool = False) -> List[int]:
        """Batched I/O (paper §3.3): one device transfer + one donated scatter
        for the whole group of experts.  host_arrays: name -> [n, ...].
        """
        if not keys:
            return []
        with self.lock:
            fresh = [k for k in keys if k not in self.table]
            if fresh:
                sel = [i for i, k in enumerate(keys) if k not in self.table]
                slots = self._allocate(len(fresh))
                stacked = {name: jax.device_put(arr[sel].astype(self.dtype))
                           for name, arr in host_arrays.items()}
                slot_arr = jnp.array(slots, jnp.int32)
                self.bufs = self._insert(self.bufs, stacked, slot_arr)
                for k, s in zip(fresh, slots):
                    self.table[k] = s
                    self.lru[k] = 1 if mark_used else 0
                    self.lru.move_to_end(k)
            # refresh LRU position of already-present keys
            for k in keys:
                if k in self.lru:
                    self.lru.move_to_end(k)
            return [self.table[k] for k in keys]

    def wait(self):
        """Barrier: ensure all in-flight buffer updates are materialized."""
        jax.block_until_ready(jax.tree.leaves(self.bufs))

    # ------------------------------------------------------------------ stats
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def reset_stats(self):
        with self.lock:
            self.hits = self.misses = self.evictions = self.prefetch_evicted = 0

    def check_invariants(self) -> bool:
        """Property-test hook: page table and LRU agree, no slot aliasing."""
        with self.lock:
            if set(self.table.keys()) != set(self.lru.keys()):
                return False
            slots = list(self.table.values())
            if len(slots) != len(set(slots)):
                return False
            if any(s < 0 or s >= self.num_slots for s in slots):
                return False
            if set(slots) & set(self.free):
                return False
            if len(slots) + len(self.free) != self.num_slots:
                return False
            return True
