"""Unified request-level serving facade (the public SP-MoE API).

The paper-experiment entry points (``greedy_generate`` / ``sd_generate`` /
``sd_generate_adaptive`` in ``core/sd.py``, ``OffloadEngine.generate`` in
``core/runtime.py``) remain as the *internal* layer; this module is the one
shape every caller goes through:

Two-axis policy model
---------------------
Serving behaviour decomposes into two orthogonal choices:

* ``DecodePolicy`` — *how tokens are proposed and committed*:
  ``greedy`` (plain autoregressive), ``sd`` (fixed-length speculative
  decoding), ``sd-adaptive`` (acceptance-EWMA-controlled draft length).
* ``OffloadPolicy`` — *where expert weights live and how they move*:
  ``none`` (all weights resident), ``spmoe`` (drafting-stage cross-model
  prefetch, paper Algorithm 1/2), ``adapmoe`` / ``moe-infinity`` /
  ``on-demand`` (the paper's baselines).

Every decode × offload combination is lossless: the emitted stream is
bit-identical to target-only greedy decoding.  Note ``greedy × spmoe``
degenerates to on-demand loading — SP-MoE's prefetch signal *is* the
drafting stage, so without drafts there is nothing to predict from.

Request lifecycle
-----------------
A long-lived :class:`Engine` serves a stream of :class:`Request` objects
against ONE warm :class:`~repro.core.cache.ExpertCache`, one prefetcher and
one set of compiled step functions; only the KV/session state is
per-request.  ``submit`` is the one-shot call; ``stream`` yields token ids
as each verify block commits (granularity: one chunk per committed block,
one token per step for greedy).  ``stop_tokens`` end a request early —
truncation happens on the committed stream, so it is honoured identically
by every decode × offload combination.

Each finished request returns a :class:`GenerationResult` carrying a
per-request :class:`Metrics` snapshot (counter deltas for exactly that
request); ``Engine.metrics()`` is the cumulative view.  The keys are the
same on every path — paths that don't exercise a counter report zero.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from enum import Enum
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cutoff import HardwareProfile
from repro.core import sd as S


class DecodePolicy(str, Enum):
    """How tokens are proposed/committed (axis 1 of the policy model)."""
    GREEDY = "greedy"
    SD = "sd"
    SD_ADAPTIVE = "sd-adaptive"


class OffloadPolicy(str, Enum):
    """Where expert weights live / how they move (axis 2)."""
    NONE = "none"
    SPMOE = "spmoe"
    ADAPMOE = "adapmoe"
    MOE_INFINITY = "moe-infinity"
    ON_DEMAND = "on-demand"


DECODE_POLICIES: Tuple[str, ...] = tuple(p.value for p in DecodePolicy)
OFFLOAD_POLICIES: Tuple[str, ...] = tuple(p.value for p in OffloadPolicy)


def derive_draft_config(cfg: ModelConfig) -> ModelConfig:
    """Default draft for a target: its dense sibling (MoE targets) or a
    half-depth copy (dense targets) — the reduced-scale stand-in for the
    paper's distilled draft models (Table 1)."""
    if cfg.is_moe:
        return dataclasses.replace(
            cfg, num_experts=0, num_experts_per_tok=0, num_shared_experts=0,
            first_dense_layers=0, name=cfg.name + "-draft")
    return dataclasses.replace(cfg, num_layers=max(2, cfg.num_layers // 2),
                               name=cfg.name + "-draft")


@dataclass
class EngineConfig:
    """Everything an :class:`Engine` needs, in one typed object (replaces the
    ``OffloadEngine.__init__`` kwarg pile and the mixed ``--policy`` string).

    ``decode`` × ``offload`` select the serving behaviour; the remaining
    fields parameterize it.  ``draft`` defaults to
    :func:`derive_draft_config` of ``model`` when a draft is needed.
    """
    model: ModelConfig
    draft: Optional[ModelConfig] = None
    decode: str = DecodePolicy.SD.value
    offload: str = OffloadPolicy.NONE.value
    # speculative decoding
    draft_len: int = 4                  # fixed N for decode == "sd"
    min_draft_len: int = 1              # adaptive controller bounds
    max_draft_len: int = 8
    draft_ewma: float = 0.5             # acceptance EWMA smoothing
    # offload plane
    cache_slots: int = 8
    cutoff: Optional[int] = None        # None -> solver/profile/all layers
    k_prefetch: Optional[int] = None    # None -> num_experts_per_tok
    prefetch_mode: str = "worker"
    batched_io: bool = True
    profile: Optional[HardwareProfile] = None
    # session
    max_seq: int = 512
    precompile: bool = True             # trace fast verify path at init

    def __post_init__(self):
        self.decode = DecodePolicy(self.decode).value
        self.offload = OffloadPolicy(self.offload).value
        if self.offload != OffloadPolicy.NONE.value and not self.model.is_moe:
            raise ValueError(
                f"offload policy {self.offload!r} requires an MoE target "
                f"(model {self.model.name!r} is dense)")
        if self.decode == DecodePolicy.SD.value and self.draft_len < 1:
            raise ValueError("decode='sd' needs draft_len >= 1")
        if not 1 <= self.min_draft_len <= self.max_draft_len:
            raise ValueError("need 1 <= min_draft_len <= max_draft_len")

    @property
    def needs_draft(self) -> bool:
        return self.decode != DecodePolicy.GREEDY.value

    def resolved_draft(self) -> ModelConfig:
        return self.draft if self.draft is not None \
            else derive_draft_config(self.model)

    @property
    def initial_draft_len(self) -> int:
        """Draft tokens per iteration at session start (0 = no drafting)."""
        if self.decode == DecodePolicy.GREEDY.value:
            return 0
        if self.decode == DecodePolicy.SD_ADAPTIVE.value:
            return self.min_draft_len
        return self.draft_len


@dataclass
class Request:
    """One generation request.  ``prompt`` is a ``[1, P]`` int array (or a
    plain list of token ids).  Generation ends after ``max_new_tokens``
    tokens or — on every decode × offload combination identically — right
    after the first emitted token in ``stop_tokens``."""
    prompt: Any
    max_new_tokens: int = 32
    stop_tokens: Sequence[int] = ()
    request_id: Optional[str] = None

    def prompt_array(self) -> jax.Array:
        p = self.prompt
        if not isinstance(p, (jax.Array, np.ndarray)):
            p = jnp.asarray([list(p)], jnp.int32)
        p = jnp.asarray(p, jnp.int32)
        if p.ndim == 1:
            p = p[None, :]
        assert p.ndim == 2 and p.shape[0] == 1, "requests are batch-1 [1, P]"
        return p


# the counters OffloadEngine.counters() exposes — the ONE list the runtime
# snapshot, the per-request delta, and the legacy stats dict all iterate
# (each name is also a Metrics field)
RUNTIME_COUNTER_KEYS = ("lookups", "hits", "on_demand_loads", "prefetched",
                        "evictions", "prefetch_evicted_unused", "host_syncs",
                        "verify_blocks", "fast_blocks", "fast_fallbacks",
                        "iterations", "drafted", "accepted")

# counter fields that accumulate / subtract when combining Metrics
_COUNTERS = ("requests", "tokens") + RUNTIME_COUNTER_KEYS


@dataclass
class Metrics:
    """One typed stats object for every serving path — identical keys
    whether the request ran greedy × none or sd-adaptive × spmoe.  Raw
    counters are stored; ratios are derived properties so per-request
    snapshots and the cumulative view stay consistent under addition."""
    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    iterations: int = 0
    drafted: int = 0
    accepted: int = 0
    # offload plane (zero when offload == "none")
    lookups: int = 0
    hits: int = 0
    on_demand_loads: int = 0
    prefetched: int = 0
    evictions: int = 0
    prefetch_evicted_unused: int = 0
    host_syncs: int = 0
    verify_blocks: int = 0
    fast_blocks: int = 0
    fast_fallbacks: int = 0
    cutoff_layer: int = -1              # configuration echo, not a counter

    # ------------------------------------------------------------- derived
    @property
    def tpot_wall(self) -> float:
        return self.wall_s / max(self.tokens, 1)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    @property
    def tokens_per_iteration(self) -> float:
        return self.tokens / max(self.iterations, 1)

    # ------------------------------------------------------------ algebra
    def add(self, other: "Metrics") -> "Metrics":
        """Accumulate ``other`` into self (cumulative view)."""
        for f in _COUNTERS:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.wall_s += other.wall_s
        self.cutoff_layer = other.cutoff_layer
        return self

    def as_dict(self) -> Dict[str, float]:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d.update(tpot_wall=self.tpot_wall, acceptance_rate=self.acceptance_rate,
                 hit_rate=self.hit_rate,
                 tokens_per_iteration=self.tokens_per_iteration)
        return d

    def __getitem__(self, key: str):
        return self.as_dict()[key]


@dataclass
class GenerationResult:
    """Outcome of one request: the committed tokens, why generation stopped
    (``"length"`` or ``"stop"``), and that request's Metrics delta."""
    tokens: List[int]
    finish_reason: str
    metrics: Metrics
    request_id: Optional[str] = None

    def token_array(self) -> jax.Array:
        return jnp.asarray(self.tokens, jnp.int32)


class _StopHit(Exception):
    """Internal: a stop token committed mid-chunk."""


class Engine:
    """Long-lived serving engine: one warm expert cache / prefetcher / set of
    compiled steps, many requests.

    ``tparams`` / ``dparams`` may be omitted, in which case the models are
    initialized from ``seed`` / ``draft_seed`` (the convention every example
    and test in this repo uses).  ``close()`` (or use as a context manager)
    stops the prefetch worker.
    """

    def __init__(self, config: EngineConfig, tparams=None, dparams=None, *,
                 seed: int = 0, draft_seed: int = 1):
        from repro.models.registry import build_model   # local: avoid cycle
        self.config = config
        self.target = build_model(config.model)
        self.tparams = tparams if tparams is not None \
            else self.target.init(jax.random.PRNGKey(seed))
        self.draft_cfg = config.resolved_draft() if config.needs_draft else None
        self.draft = build_model(self.draft_cfg) if self.draft_cfg else None
        self.dparams = None
        if self.draft is not None:
            self.dparams = dparams if dparams is not None \
                else self.draft.init(jax.random.PRNGKey(draft_seed))
        self.runtime = None             # OffloadEngine when offload != none
        if config.offload != OffloadPolicy.NONE.value:
            from repro.core.runtime import OffloadEngine
            self.runtime = OffloadEngine(config, self.tparams, self.dparams,
                                         target=self.target, draft=self.draft)
        # per-engine compiled-step caches (warm across requests)
        self._sd_steps: Dict[int, Any] = {}
        self._greedy_step = None
        self._cum = Metrics(cutoff_layer=self.cutoff_layer)
        self.last_result: Optional[GenerationResult] = None
        self._closed = False

    # ----------------------------------------------------------- properties
    @property
    def cutoff_layer(self) -> int:
        return self.runtime.cutoff if self.runtime is not None else -1

    # ------------------------------------------------------------- serving
    def submit(self, request: Request) -> GenerationResult:
        """One-shot: run the request to completion, return the result."""
        for _ in self.stream(request):
            pass
        return self.last_result

    def stream(self, request: Request) -> Iterator[int]:
        """Yield token ids as each verify block commits.  After exhaustion
        the request's :class:`GenerationResult` is at ``self.last_result``."""
        assert not self._closed, "engine is closed"
        prompt = request.prompt_array()
        need = prompt.shape[1] + request.max_new_tokens + \
            self._max_block_len() + 1
        assert need <= self.config.max_seq, (
            f"request needs {need} positions but max_seq is "
            f"{self.config.max_seq}; raise EngineConfig.max_seq")
        stop = set(int(t) for t in request.stop_tokens)
        before = self._counters()
        sstats: Dict[str, Any] = {"iterations": 0, "drafted": 0, "accepted": 0}
        gen = self._chunk_stream(prompt, request.max_new_tokens, sstats)
        emitted: List[int] = []
        finish = "length"
        # wall_s accumulates only time spent INSIDE the chunk generator (the
        # decode work), not consumer time between yields — so streamed and
        # one-shot requests report comparable per-request latency.
        wall = 0.0
        try:
            while True:
                t0 = time.perf_counter()
                try:
                    chunk = next(gen)
                except StopIteration:
                    wall += time.perf_counter() - t0
                    break
                wall += time.perf_counter() - t0
                for tok in chunk:
                    emitted.append(int(tok))
                    yield int(tok)
                    if int(tok) in stop:
                        finish = "stop"
                        raise _StopHit
        except _StopHit:
            pass
        finally:
            t0 = time.perf_counter()
            gen.close()               # offload path drains the prefetcher
            wall += time.perf_counter() - t0
            self.last_result = self._finish(request, emitted, finish, wall,
                                            before, sstats)

    def metrics(self) -> Metrics:
        """Cumulative Metrics across every request this engine served."""
        return dataclasses.replace(self._cum)

    def reset_stats(self):
        """Zero the cumulative counters (engine + cache + prefetcher) so a
        warmed engine reports clean steady-state numbers."""
        self._cum = Metrics(cutoff_layer=self.cutoff_layer)
        if self.runtime is not None:
            self.runtime.reset_stats()

    def close(self):
        if not self._closed and self.runtime is not None:
            self.runtime.close()
        self._closed = True

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ internals
    def _max_block_len(self) -> int:
        cfg = self.config
        if cfg.decode == DecodePolicy.SD_ADAPTIVE.value:
            return cfg.max_draft_len + 1
        return cfg.initial_draft_len + 1

    def _chunk_stream(self, prompt, max_new_tokens, sstats):
        """The per-combination committed-chunk generator."""
        cfg = self.config
        if self.runtime is not None:
            return self.runtime.generate_stream(prompt, max_new_tokens)
        if cfg.decode == DecodePolicy.GREEDY.value:
            if self._greedy_step is None:
                self._greedy_step = S.make_greedy_step(self.target)
            return S.greedy_stream(self.target, self.tparams, prompt,
                                   max_new_tokens, cfg.max_seq,
                                   stats=sstats, step=self._greedy_step)
        if cfg.decode == DecodePolicy.SD.value:
            step = self._sd_step_for(cfg.draft_len)
            return S.sd_stream(self.draft, self.target, self.dparams,
                               self.tparams, prompt, max_new_tokens,
                               cfg.draft_len, cfg.max_seq,
                               stats=sstats, step=step)
        return S.sd_adaptive_stream(self.draft, self.target, self.dparams,
                                    self.tparams, prompt, max_new_tokens,
                                    cfg.max_seq, min_len=cfg.min_draft_len,
                                    max_len=cfg.max_draft_len,
                                    ewma=cfg.draft_ewma, stats=sstats,
                                    step_for=self._sd_step_for)

    def _sd_step_for(self, n: int):
        if n not in self._sd_steps:
            self._sd_steps[n] = jax.jit(
                S.make_sd_step(self.draft, self.target, n))
        return self._sd_steps[n]

    def _counters(self) -> Dict[str, int]:
        return self.runtime.counters() if self.runtime is not None else {}

    def _finish(self, request, emitted, finish, wall, before, sstats
                ) -> GenerationResult:
        after = self._counters()
        m = Metrics(requests=1, tokens=len(emitted), wall_s=wall,
                    cutoff_layer=self.cutoff_layer)
        if after:
            for k in RUNTIME_COUNTER_KEYS:
                setattr(m, k, after[k] - before.get(k, 0))
        else:
            m.iterations = sstats["iterations"]
            m.drafted = sstats["drafted"]
            m.accepted = sstats["accepted"]
        self._cum.add(m)
        return GenerationResult(tokens=emitted, finish_reason=finish,
                                metrics=m, request_id=request.request_id)
