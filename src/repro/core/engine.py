"""Unified request-level serving facade (the public SP-MoE API).

The paper-experiment entry points (``greedy_generate`` / ``sd_generate`` /
``sd_generate_adaptive`` in ``core/sd.py``, ``OffloadEngine.generate`` in
``core/runtime.py``) remain as the *internal* layer; this module is the one
shape every caller goes through:

Two-axis policy model
---------------------
Serving behaviour decomposes into two orthogonal choices:

* ``DecodePolicy`` — *how tokens are proposed and committed*:
  ``greedy`` (plain autoregressive), ``sd`` (fixed-length speculative
  decoding), ``sd-adaptive`` (acceptance-EWMA-controlled draft length).
* ``OffloadPolicy`` — *where expert weights live and how they move*:
  ``none`` (all weights resident), ``spmoe`` (drafting-stage cross-model
  prefetch, paper Algorithm 1/2), ``adapmoe`` / ``moe-infinity`` /
  ``on-demand`` (the paper's baselines).

Every decode × offload combination is lossless: the emitted stream is
bit-identical to target-only greedy decoding.  Note ``greedy × spmoe``
degenerates to on-demand loading — SP-MoE's prefetch signal *is* the
drafting stage, so without drafts there is nothing to predict from.

Request lifecycle
-----------------
A long-lived :class:`Engine` serves a stream of :class:`Request` objects
against ONE warm :class:`~repro.core.cache.ExpertCache`, one prefetcher and
one set of compiled step functions; everything a single request mutates
lives in a :class:`Session`.  ``submit`` is the one-shot call; ``stream``
yields token ids as each verify block commits (granularity: one chunk per
committed block, one token per step for greedy); ``serve`` round-robins up
to N concurrent sessions over the same warm runtime, one committed verify
block per session per turn — interleaving is lossless, every session's
stream is bit-identical to serving it alone.  ``stop_tokens`` end a request
early — truncation happens on the committed stream, so it is honoured
identically by every decode × offload combination — and a consumer that
abandons ``stream``/``serve`` mid-flight retires the session with
``finish_reason="aborted"``, leaving the engine warm and reusable.

Each finished request returns a :class:`GenerationResult` carrying a
per-request :class:`Metrics` snapshot (counter deltas for exactly that
request, accrued turn-by-turn so interleaved sessions stay isolated);
``Engine.metrics()`` is the cumulative view.  The keys are the same on
every path — paths that don't exercise a counter report zero.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from enum import Enum
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.chaos import ChaosConfig, ExpertLoadError
from repro.core.cutoff import HardwareProfile
from repro.core import sd as S


class DecodePolicy(str, Enum):
    """How tokens are proposed/committed (axis 1 of the policy model)."""
    GREEDY = "greedy"
    SD = "sd"
    SD_ADAPTIVE = "sd-adaptive"


class OffloadPolicy(str, Enum):
    """Where expert weights live / how they move (axis 2)."""
    NONE = "none"
    SPMOE = "spmoe"
    ADAPMOE = "adapmoe"
    MOE_INFINITY = "moe-infinity"
    ON_DEMAND = "on-demand"


DECODE_POLICIES: Tuple[str, ...] = tuple(p.value for p in DecodePolicy)
OFFLOAD_POLICIES: Tuple[str, ...] = tuple(p.value for p in OffloadPolicy)


def derive_draft_config(cfg: ModelConfig) -> ModelConfig:
    """Default draft for a target: its dense sibling (MoE targets) or a
    half-depth copy (dense targets) — the reduced-scale stand-in for the
    paper's distilled draft models (Table 1)."""
    if cfg.is_moe:
        return dataclasses.replace(
            cfg, num_experts=0, num_experts_per_tok=0, num_shared_experts=0,
            first_dense_layers=0, name=cfg.name + "-draft")
    return dataclasses.replace(cfg, num_layers=max(2, cfg.num_layers // 2),
                               name=cfg.name + "-draft")


@dataclass
class EngineConfig:
    """Everything an :class:`Engine` needs, in one typed object (replaces the
    ``OffloadEngine.__init__`` kwarg pile and the mixed ``--policy`` string).

    ``decode`` × ``offload`` select the serving behaviour; the remaining
    fields parameterize it.  ``draft`` defaults to
    :func:`derive_draft_config` of ``model`` when a draft is needed.
    """
    model: ModelConfig
    draft: Optional[ModelConfig] = None
    decode: str = DecodePolicy.SD.value
    offload: str = OffloadPolicy.NONE.value
    # speculative decoding
    draft_len: int = 4                  # fixed N for decode == "sd"
    min_draft_len: int = 1              # adaptive controller bounds
    max_draft_len: int = 8
    draft_ewma: float = 0.5             # acceptance EWMA smoothing
    # offload plane
    cache_slots: int = 8
    cutoff: Optional[int] = None        # None -> solver/profile/all layers
    k_prefetch: Optional[int] = None    # None -> num_experts_per_tok
    prefetch_mode: str = "worker"
    batched_io: bool = True
    profile: Optional[HardwareProfile] = None
    # session
    max_seq: int = 512
    precompile: bool = True             # trace fast verify path at init
    # resilience plane (see core/chaos.py + the Prefetcher docstring):
    # every knob defaults to today's behaviour — retries on transient I/O,
    # no fault injection, checksums only when chaos is enabled
    chaos: Optional[ChaosConfig] = None
    prefetch_retries: int = 3           # per-task transient-I/O retry budget
    retry_backoff_s: float = 0.002      # exponential backoff base
    task_timeout_s: Optional[float] = None   # per prefetch-task deadline
    drain_timeout_s: float = 30.0       # bound on per-session I/O waits
    verify_payloads: Optional[bool] = None   # None -> on iff chaos enabled
    max_worker_restarts: int = 3        # supervised-worker restart budget
    fail_threshold: int = 3             # consecutive failures -> degraded
    heartbeat_timeout_s: float = 10.0   # wedged-worker detection
    io_retries: int = 3                 # on-demand (decode-critical) retries

    def __post_init__(self):
        self.decode = DecodePolicy(self.decode).value
        self.offload = OffloadPolicy(self.offload).value
        if self.offload != OffloadPolicy.NONE.value and not self.model.is_moe:
            raise ValueError(
                f"offload policy {self.offload!r} requires an MoE target "
                f"(model {self.model.name!r} is dense)")
        if self.decode == DecodePolicy.SD.value and self.draft_len < 1:
            raise ValueError("decode='sd' needs draft_len >= 1")
        if not 1 <= self.min_draft_len <= self.max_draft_len:
            raise ValueError("need 1 <= min_draft_len <= max_draft_len")

    @property
    def needs_draft(self) -> bool:
        return self.decode != DecodePolicy.GREEDY.value

    @property
    def resolved_verify_payloads(self) -> bool:
        """Checksum verification of fetched payloads: explicit setting wins;
        otherwise it is on exactly when fault injection is configured (a
        chaos run without checksums could insert corrupted weights)."""
        if self.verify_payloads is not None:
            return self.verify_payloads
        return self.chaos is not None and self.chaos.enabled

    def resolved_draft(self) -> ModelConfig:
        return self.draft if self.draft is not None \
            else derive_draft_config(self.model)

    @property
    def initial_draft_len(self) -> int:
        """Draft tokens per iteration at session start (0 = no drafting)."""
        if self.decode == DecodePolicy.GREEDY.value:
            return 0
        if self.decode == DecodePolicy.SD_ADAPTIVE.value:
            return self.min_draft_len
        return self.draft_len


@dataclass
class Request:
    """One generation request.  ``prompt`` is a ``[1, P]`` int array (or a
    plain list of token ids).  Generation ends after ``max_new_tokens``
    tokens or — on every decode × offload combination identically — right
    after the first emitted token in ``stop_tokens``.  ``deadline_s`` is a
    per-request wall-clock budget measured from the first decode turn: an
    expired session is retired with ``finish_reason="deadline"`` (already-
    committed tokens are kept) instead of wedging its batchmates' rounds."""
    prompt: Any
    max_new_tokens: int = 32
    stop_tokens: Sequence[int] = ()
    request_id: Optional[str] = None
    deadline_s: Optional[float] = None

    def prompt_array(self) -> jax.Array:
        p = self.prompt
        if not isinstance(p, (jax.Array, np.ndarray)):
            p = jnp.asarray([list(p)], jnp.int32)
        p = jnp.asarray(p, jnp.int32)
        if p.ndim == 1:
            p = p[None, :]
        assert p.ndim == 2 and p.shape[0] == 1, "requests are batch-1 [1, P]"
        return p


# the counters OffloadEngine.counters() exposes — the ONE list the runtime
# snapshot, the per-request delta, and the legacy stats dict all iterate
# (each name is also a Metrics field)
RUNTIME_COUNTER_KEYS = ("lookups", "hits", "on_demand_loads", "prefetched",
                        "evictions", "prefetch_evicted_unused", "host_syncs",
                        "verify_blocks", "fast_blocks", "fast_fallbacks",
                        "iterations", "drafted", "accepted",
                        # resilience plane (prefetcher/store health)
                        "prefetch_errors", "prefetch_retries",
                        "checksum_failures", "worker_restarts",
                        "degraded_rounds", "io_errors")

# counter fields that accumulate / subtract when combining Metrics
_COUNTERS = ("requests", "tokens") + RUNTIME_COUNTER_KEYS


@dataclass
class Metrics:
    """One typed stats object for every serving path — identical keys
    whether the request ran greedy × none or sd-adaptive × spmoe.  Raw
    counters are stored; ratios are derived properties so per-request
    snapshots and the cumulative view stay consistent under addition."""
    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    iterations: int = 0
    drafted: int = 0
    accepted: int = 0
    # offload plane (zero when offload == "none")
    lookups: int = 0
    hits: int = 0
    on_demand_loads: int = 0
    prefetched: int = 0
    evictions: int = 0
    prefetch_evicted_unused: int = 0
    host_syncs: int = 0
    verify_blocks: int = 0
    fast_blocks: int = 0
    fast_fallbacks: int = 0
    # resilience plane (zero on a healthy run)
    prefetch_errors: int = 0
    prefetch_retries: int = 0
    checksum_failures: int = 0
    worker_restarts: int = 0
    degraded_rounds: int = 0
    io_errors: int = 0
    cutoff_layer: int = -1              # configuration echo, not a counter

    # ------------------------------------------------------------- derived
    @property
    def tpot_wall(self) -> float:
        return self.wall_s / max(self.tokens, 1)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    @property
    def tokens_per_iteration(self) -> float:
        return self.tokens / max(self.iterations, 1)

    # ------------------------------------------------------------ algebra
    def add(self, other: "Metrics") -> "Metrics":
        """Accumulate ``other`` into self (cumulative view)."""
        for f in _COUNTERS:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.wall_s += other.wall_s
        if other.cutoff_layer >= 0:      # -1 = "no offload plane": adding a
            self.cutoff_layer = other.cutoff_layer  # default-constructed
        return self                      # Metrics must not wipe the echo

    def as_dict(self) -> Dict[str, float]:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d.update(tpot_wall=self.tpot_wall, acceptance_rate=self.acceptance_rate,
                 hit_rate=self.hit_rate,
                 tokens_per_iteration=self.tokens_per_iteration)
        return d

    def __getitem__(self, key: str):
        return self.as_dict()[key]


@dataclass
class GenerationResult:
    """Outcome of one request: the committed tokens, why generation stopped
    (``"length"``, ``"stop"``, ``"aborted"`` when the consumer abandoned
    the stream, ``"deadline"`` when the request's wall-clock budget
    expired, ``"cancelled"`` for an explicit :meth:`Session.cancel`, or
    ``"io_error"`` when the offload plane could not load an expert even
    synchronously — the degradation ladder's final rung; committed tokens
    are always a prefix of the fault-free stream, never wrong), and that
    request's Metrics delta."""
    tokens: List[int]
    finish_reason: str
    metrics: Metrics
    request_id: Optional[str] = None

    def token_array(self) -> jax.Array:
        return jnp.asarray(self.tokens, jnp.int32)


class Session:
    """One in-flight request on a (possibly shared) :class:`Engine`.

    Owns the per-request plane: the committed-chunk generator — whose frame
    holds the KV/draft/decode state, lazily started on the first ``turn`` so
    admission order is scheduler-controlled — the emitted-token buffer, the
    finish reason, the wall clock, and a counter-delta LEDGER.  The ledger
    accrues per-turn deltas of the engine-global cumulative counters; a
    single before/after snapshot (how PR 3's serial ``stream`` computed
    per-request metrics) would charge one session with every other session's
    interleaved blocks, so deltas are taken around each generator step
    instead — this is what keeps the per-request Metrics contract intact
    under interleaving.

    Scheduling protocol: call :meth:`turn` repeatedly; each call commits at
    most one verify block (decode-policy-aware — greedy turns commit one
    token, sd/sd-adaptive turns one draft-then-verify block) and returns the
    newly committed tokens, or None once the session is done.  A stop token
    finishes the session mid-chunk; :meth:`abort` retires an abandoned
    session with ``finish_reason="aborted"`` while leaving the engine warm
    and reusable.
    """

    def __init__(self, engine: "Engine", request: Request):
        assert not engine._closed, "engine is closed"
        self.engine = engine
        self.request = request
        self._prompt = request.prompt_array()
        need = self._prompt.shape[1] + request.max_new_tokens + \
            engine._max_block_len() + 1
        assert need <= engine.config.max_seq, (
            f"request needs {need} positions but max_seq is "
            f"{engine.config.max_seq}; raise EngineConfig.max_seq")
        self._stop = set(int(t) for t in request.stop_tokens)
        self.sstats: Dict[str, Any] = {"iterations": 0, "drafted": 0,
                                       "accepted": 0}
        # offload runtimes drive the DecodeState turn API directly (so the
        # serve scheduler can gather several sessions' blocks into one
        # batched verify round); non-offload paths keep the chunk generator
        self.dstate = None              # runtime DecodeState, lazily started
        self.gen = None if engine.runtime is not None else \
            engine._chunk_stream(self._prompt, request.max_new_tokens,
                                 self.sstats)
        self.ledger: Dict[str, int] = {k: 0 for k in RUNTIME_COUNTER_KEYS}
        self.emitted: List[int] = []
        self.wall = 0.0                 # decode-side time, not consumer time
        self.result: Optional[GenerationResult] = None
        # per-request deadline: armed on the first decode turn so queueing
        # time behind a long backlog doesn't consume the request's budget
        self._deadline: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.result is not None

    def expired(self) -> bool:
        """True once the request's wall-clock budget (deadline_s) is spent.
        The clock arms on the first decode turn, so time spent queued
        behind a backlog doesn't count against the request."""
        return self._deadline is not None and time.monotonic() > self._deadline

    def _arm_deadline(self):
        if self._deadline is None and self.request.deadline_s is not None:
            self._deadline = time.monotonic() + self.request.deadline_s

    def cancel(self, reason: str = "cancelled"):
        """Retire an unfinished session early (idempotent).  The decode side
        is closed — this session's in-flight prefetch tasks are waited out
        (bounded) and its counters committed — so batchmates and the warm
        engine are unaffected: the session falls out of the scheduling
        round the way a finished one does."""
        if not self.done:
            self._finalize(reason)

    def _step(self, fn):
        """Run one decode-side step under this session's wall clock and
        counter ledger (per-turn engine-counter deltas)."""
        before = self.engine._counters()
        t0 = time.perf_counter()
        try:
            return fn()
        finally:
            self.wall += time.perf_counter() - t0
            after = self.engine._counters()
            for k in self.ledger:
                self.ledger[k] += after.get(k, 0) - before.get(k, 0)

    def _advance(self) -> Optional[List[int]]:
        """One solo decode step: start the runtime session on first use
        (prefill), then one committed verify block; None when exhausted."""
        rt = self.engine.runtime
        if rt is not None:
            if self.dstate is None:
                self.dstate = rt.start_session(self._prompt,
                                               self.request.max_new_tokens)
            return rt.session_turn(self.dstate)
        try:
            return next(self.gen)
        except StopIteration:
            return None

    def _close_decode(self):
        """Retire the decode side (waits out this session's prefetch tasks
        and commits its device-side counters)."""
        if self.engine.runtime is not None:
            if self.dstate is not None:
                self.engine.runtime.finish_session(self.dstate)
        else:
            self.gen.close()

    def turn(self) -> Optional[List[int]]:
        """Advance one committed verify block.  Returns the newly committed
        tokens (truncated right after a stop token) or None when done.  An
        expired deadline retires the session (``finish_reason="deadline"``)
        and an unrecoverable expert load — the degradation ladder's final
        rung — retires it with ``"io_error"``; neither raises."""
        if self.done:
            return None
        if self.expired():
            self._finalize("deadline")
            return None
        self._arm_deadline()
        try:
            chunk = self._step(self._advance)
        except ExpertLoadError:
            self._finalize("io_error")
            return None
        return self._commit_chunk(chunk)

    def deliver(self, chunk, delta: Dict[str, int],
                wall: float) -> Optional[List[int]]:
        """Commit a chunk produced by a batched cross-session round
        (``OffloadEngine.session_turns``): fold the round's per-session
        counter delta and this session's own decode wall time (measured
        per-phase by the runtime — a batchmate's miss fallback is not
        charged here) into the ledger, then run the same
        stop-token/finalize logic as a solo :meth:`turn`.  A chunk that is
        an :class:`ExpertLoadError` (this session's block could not load
        its experts even synchronously) retires the session with
        ``finish_reason="io_error"`` — its batchmates are untouched."""
        if self.done:
            return None
        for k in self.ledger:
            self.ledger[k] += delta.get(k, 0)
        self.wall += wall
        if isinstance(chunk, ExpertLoadError):
            self._finalize("io_error")
            return None
        return self._commit_chunk(chunk)

    def _commit_chunk(self, chunk: Optional[List[int]]
                      ) -> Optional[List[int]]:
        if chunk is None:
            self._finalize("length")
            return None
        out: List[int] = []
        for tok in chunk:
            tok = int(tok)
            self.emitted.append(tok)
            out.append(tok)
            if tok in self._stop:
                self._finalize("stop")
                break
        return out

    def abort(self):
        """Retire an unfinished session as ``"aborted"`` (no-op when already
        finished): the decode side is closed — which waits out this
        session's prefetch tasks and commits its counters — so the engine
        stays warm and immediately reusable."""
        if not self.done:
            self._finalize("aborted")

    def _finalize(self, finish: str):
        self._step(self._close_decode)  # offload path retires its DecodeState
        m = Metrics(requests=1, tokens=len(self.emitted), wall_s=self.wall,
                    cutoff_layer=self.engine.cutoff_layer)
        if self.engine.runtime is not None:
            for k, v in self.ledger.items():
                setattr(m, k, v)
            if self.dstate is not None:
                # I/O counters come from the session's owner-attributed
                # ledger (finalized by finish_session above): a prefetch
                # load belongs to the session whose task fetched it, not to
                # whichever session's turn it happened to land in
                for k, v in self.dstate.io.items():
                    setattr(m, k, v)
        else:
            m.iterations = self.sstats["iterations"]
            m.drafted = self.sstats["drafted"]
            m.accepted = self.sstats["accepted"]
        self.result = GenerationResult(tokens=list(self.emitted),
                                       finish_reason=finish, metrics=m,
                                       request_id=self.request.request_id)
        self.engine._cum.add(m)
        self.engine.last_result = self.result


class Engine:
    """Long-lived serving engine: one warm expert cache / prefetcher / set of
    compiled steps, many requests.

    ``tparams`` / ``dparams`` may be omitted, in which case the models are
    initialized from ``seed`` / ``draft_seed`` (the convention every example
    and test in this repo uses).  ``close()`` (or use as a context manager)
    stops the prefetch worker.
    """

    def __init__(self, config: EngineConfig, tparams=None, dparams=None, *,
                 seed: int = 0, draft_seed: int = 1):
        from repro.models.registry import build_model   # local: avoid cycle
        self.config = config
        self.target = build_model(config.model)
        self.tparams = tparams if tparams is not None \
            else self.target.init(jax.random.PRNGKey(seed))
        self.draft_cfg = config.resolved_draft() if config.needs_draft else None
        self.draft = build_model(self.draft_cfg) if self.draft_cfg else None
        self.dparams = None
        if self.draft is not None:
            self.dparams = dparams if dparams is not None \
                else self.draft.init(jax.random.PRNGKey(draft_seed))
        self.runtime = None             # OffloadEngine when offload != none
        if config.offload != OffloadPolicy.NONE.value:
            from repro.core.runtime import OffloadEngine
            self.runtime = OffloadEngine(config, self.tparams, self.dparams,
                                         target=self.target, draft=self.draft)
        # per-engine compiled-step caches (warm across requests)
        self._sd_steps: Dict[int, Any] = {}
        self._greedy_step = None
        self._cum = Metrics(cutoff_layer=self.cutoff_layer)
        self.last_result: Optional[GenerationResult] = None
        self.last_batch: List[GenerationResult] = []
        self._closed = False

    # ----------------------------------------------------------- properties
    @property
    def cutoff_layer(self) -> int:
        return self.runtime.cutoff if self.runtime is not None else -1

    # ------------------------------------------------------------- serving
    def submit(self, request: Request) -> GenerationResult:
        """One-shot: run the request to completion, return the result."""
        session = Session(self, request)
        while session.turn() is not None:
            pass
        return session.result

    def stream(self, request: Request) -> Iterator[int]:
        """Yield token ids as each verify block commits.  After exhaustion
        the request's :class:`GenerationResult` is at ``self.last_result``.
        If the consumer abandons the generator mid-stream the request is
        retired with ``finish_reason="aborted"`` and the engine stays warm
        and reusable.  wall_s accumulates only decode-side time (inside the
        chunk generator), not consumer time between yields — so streamed
        and one-shot requests report comparable per-request latency."""
        session = Session(self, request)
        try:
            while True:
                chunk = session.turn()
                if chunk is None:
                    break
                for tok in chunk:
                    yield tok
                if session.done:       # stop token committed mid-chunk
                    break
        finally:
            session.abort()            # no-op unless abandoned mid-stream

    def serve(self, requests: Sequence[Request], *, concurrency: int = 2
              ) -> Iterator[Tuple[str, int]]:
        """Round-robin scheduler: up to ``concurrency`` sessions at a time
        interleave ONE committed verify block per turn on the single warm
        ExpertCache / Prefetcher / compiled-step set; further requests are
        admitted as sessions finish.  Turns are decode-policy-aware by
        construction — greedy turns commit 1 token, sd / sd-adaptive turns
        one draft-then-verify block of that session's current draft length.

        With an offload runtime, each scheduling round gathers the ready
        sessions' draft blocks into ONE fused cross-session verify dispatch
        (one routing pass, one page-table gather, one ``cache_moe`` launch,
        ≤2 host syncs per ROUND instead of 2 per session) — concurrency
        makes the hot path cheaper than serial, not merely not-worse.  A
        session that misses falls back alone without dragging its
        batchmates off the fast path.

        Yields ``(request_id, token)`` pairs in commit order (request_id
        falls back to ``"req-<index>"``).  ``self.last_batch`` is reset to
        ``[]`` on this call and holds the per-request
        :class:`GenerationResult` list (submission order) once the iterator
        finishes — including early ``close()`` after the first ``next()``,
        which aborts unfinished sessions; a never-started iterator leaves
        it ``[]``, never a previous batch's results.  Interleaving is
        lossless: each session's token stream is bit-identical to serving
        its request alone (tests/test_sessions.py)."""
        assert concurrency >= 1
        sessions = [Session(self, r) for r in requests]
        names = [s.request.request_id or f"req-{i}"
                 for i, s in enumerate(sessions)]
        self.last_batch = []
        return self._serve_iter(names, sessions, concurrency)

    def _serve_iter(self, names: List[str], sessions: List["Session"],
                    concurrency: int) -> Iterator[Tuple[str, int]]:
        try:
            waiting = list(zip(names, sessions))
            active: List[Tuple[str, Session]] = []
            while active or waiting:
                while waiting and len(active) < concurrency:
                    active.append(waiting.pop(0))
                # deadline sweep: an expired session falls out of the round
                # the way a finished one does — it is retired here (its own
                # prefetch tasks waited out, counters committed) instead of
                # wedging its batchmates' fused verify dispatch
                for _, s in active:
                    if not s.done and s.expired():
                        s.cancel("deadline")
                # batched cross-session round: every started runtime session
                # advances through ONE fused verify dispatch (one routing
                # pass / table gather / cache_moe launch, ≤2 host syncs for
                # the whole round); fresh admissions run their prefill solo
                # first, and non-offload engines always turn solo.
                round_sts = [s for _, s in active
                             if not s.done and s.dstate is not None]
                delivered: Dict[int, Optional[List[int]]] = {}
                if round_sts:
                    res = self.runtime.session_turns(
                        [s.dstate for s in round_sts])
                    for s, (chunk, delta, wall) in zip(round_sts, res):
                        delivered[id(s)] = s.deliver(chunk, delta, wall)
                for name, s in list(active):
                    chunk = delivered[id(s)] if id(s) in delivered \
                        else s.turn()
                    if s.done:
                        active.remove((name, s))
                    for tok in chunk or ():
                        yield name, tok
        finally:
            for s in sessions:
                s.abort()              # no-op on finished sessions
            self.last_batch = [s.result for s in sessions]

    def serve_all(self, requests: Sequence[Request], *, concurrency: int = 2
                  ) -> List[GenerationResult]:
        """Drain :meth:`serve`; returns the results in request order."""
        for _ in self.serve(requests, concurrency=concurrency):
            pass
        return self.last_batch

    def metrics(self) -> Metrics:
        """Cumulative Metrics across every request this engine served."""
        return dataclasses.replace(self._cum)

    def reset_stats(self):
        """Zero the cumulative counters (engine + cache + prefetcher) so a
        warmed engine reports clean steady-state numbers."""
        self._cum = Metrics(cutoff_layer=self.cutoff_layer)
        if self.runtime is not None:
            self.runtime.reset_stats()

    def close(self):
        if not self._closed and self.runtime is not None:
            self.runtime.close()
        self._closed = True

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ internals
    def _max_block_len(self) -> int:
        cfg = self.config
        if cfg.decode == DecodePolicy.SD_ADAPTIVE.value:
            return cfg.max_draft_len + 1
        return cfg.initial_draft_len + 1

    def _chunk_stream(self, prompt, max_new_tokens, sstats):
        """The committed-chunk generator for engines WITHOUT an offload
        runtime (offload == none).  Runtime-backed sessions drive the
        DecodeState turn API directly instead (see Session._advance), so the
        serve scheduler can batch several sessions into one verify round."""
        cfg = self.config
        assert self.runtime is None
        if cfg.decode == DecodePolicy.GREEDY.value:
            if self._greedy_step is None:
                self._greedy_step = S.make_greedy_step(self.target)
            return S.greedy_stream(self.target, self.tparams, prompt,
                                   max_new_tokens, cfg.max_seq,
                                   stats=sstats, step=self._greedy_step)
        if cfg.decode == DecodePolicy.SD.value:
            step = self._sd_step_for(cfg.draft_len)
            return S.sd_stream(self.draft, self.target, self.dparams,
                               self.tparams, prompt, max_new_tokens,
                               cfg.draft_len, cfg.max_seq,
                               stats=sstats, step=step)
        return S.sd_adaptive_stream(self.draft, self.target, self.dparams,
                                    self.tparams, prompt, max_new_tokens,
                                    cfg.max_seq, min_len=cfg.min_draft_len,
                                    max_len=cfg.max_draft_len,
                                    ewma=cfg.draft_ewma, stats=sstats,
                                    step_for=self._sd_step_for)

    def _sd_step_for(self, n: int):
        if n not in self._sd_steps:
            self._sd_steps[n] = jax.jit(
                S.make_sd_step(self.draft, self.target, n))
        return self._sd_steps[n]

    def _counters(self) -> Dict[str, int]:
        """Host-only snapshot of the runtime's cumulative counters (empty
        without an offload plane) — cheap enough that Session ledgers take
        it around every turn."""
        return self.runtime.counters() if self.runtime is not None else {}
