"""Event-driven latency simulator for SD-enabled MoE offloading (the
quantitative reproduction vehicle — this container has no GPU/PCIe, so the
paper's TPOT figures are regenerated from the calibrated analytical model the
paper itself builds in §3.2).

Two resources with their own timelines: COMPUTE (device) and IO (host->device
link).  A decode iteration simulates:

  drafting      N draft tokens × L_draft layers of draft compute; the IO
                stream is otherwise idle, so prefetch tasks issued by the
                policy run concurrently (SP-MoE / MoE-Infinity).
  verification  per target layer: attention compute, then expert FFN compute,
                which cannot start before the layer's activated experts have
                ARRIVED (per-key arrival times; in-flight prefetches are
                waited on just-in-time); missing experts are loaded on demand,
                queued FIFO behind outstanding prefetch I/O (bandwidth
                contention, Observation II).

Activations are sampled from per-layer Zipf popularity with token-to-token
overlap (Observation I) and cross-model predictor accuracy (Fig. 7b).

Hit-rate accounting matches Table 3: per verification block, each UNIQUE
activated expert counts one lookup; a hit means it was resident (or in
flight) BEFORE the block's own on-demand loads.

Baseline fidelity:
  on-demand      Mixtral-Offloading: per-layer partitioned LRU rings (the
                 original system caches a fixed number of experts per layer).
  moe-infinity   request-level, history-ranked prefetch, depth-unbounded but
                 budget-capped; refreshed each iteration (over-prefetch
                 pollutes the cache and contends for bandwidth).
  adapmoe        same-model gating predicts ONE expert of layer l+1 after
                 layer l's gate; synchronous (vanilla) prefetch stalls.
  spmoe          drafting-stage cross-model prefetch for layers 0..cutoff,
                 async worker + batched I/O + LRU.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class SimModel:
    """Calibration constants for one draft/target pair (paper Table 1 + §5)."""
    name: str
    num_layers: int
    num_experts: int
    top_k: int                 # experts activated per token per layer
    k_prefetch: int            # paper's critical-expert count (k in Alg. 1)
    expert_mb: float           # one expert's weight bytes (MB)
    t_comp_attn: float         # target per-layer attention+gate compute (s)
    t_comp_expert: float       # target per-expert FFN compute (s)
    t_comp_draft_layer: float  # draft per-layer compute (s)
    acceptance: float          # draft acceptance rate (Table 1 AC)
    predictor_acc: float       # cross-model top-k prediction accuracy (Fig 7b)
    zipf_a: float = 1.2        # expert popularity skew
    shared_experts: int = 0    # always-resident shared experts (deepseek)
    non_expert_gb: float = 5.0 # resident non-expert + draft + KV footprint


# Calibration: RTX-4090-class compute, PCIe 4.0x16 link (paper Table 2 env 2).
# Expert sizes / per-expert load times follow §2.2, §5.1 (336 MB -> ~14 ms,
# 150 MB -> ~6 ms, 16.5 MB -> ~0.6 ms at ~24 GB/s effective).
# Per-layer compute and drafting times are set so a baseline iteration
# splits ~69% expert loading / ~16% drafting / ~15% compute (paper Fig. 4).
MIXTRAL = SimModel("mixtral-8x7b", 32, 8, 2, 1, 336.0,
                   t_comp_attn=2.5e-3, t_comp_expert=2.0e-3,
                   t_comp_draft_layer=7.3e-3, acceptance=0.9742,
                   predictor_acc=0.88, zipf_a=0.7, non_expert_gb=5.0)
PHI_MOE = SimModel("phi-3.5-moe", 32, 16, 2, 2, 150.0,
                   t_comp_attn=1.2e-3, t_comp_expert=8.0e-4,
                   t_comp_draft_layer=2.4e-3, acceptance=0.9013,
                   predictor_acc=0.88, zipf_a=0.7, non_expert_gb=4.5)
DEEPSEEK = SimModel("deepseek-v2-lite-16b", 26, 64, 6, 6, 16.5,
                    t_comp_attn=1.0e-3, t_comp_expert=1.2e-4,
                    t_comp_draft_layer=2.5e-3, acceptance=0.9701,
                    predictor_acc=0.8894, zipf_a=0.7, non_expert_gb=7.0)
SIM_MODELS = {m.name: m for m in (MIXTRAL, PHI_MOE, DEEPSEEK)}


@dataclass
class SimEnv:
    """Hardware environment (paper Table 2)."""
    name: str
    pcie_gbps: float           # effective host->device bandwidth
    compute_scale: float       # device time multiplier vs the 4090 baseline
    gpu_mem_gb: float


ENVS = {
    "3090": SimEnv("3090", 22.0, 1.45, 24.0),
    "4090": SimEnv("4090", 24.0, 1.00, 24.0),
    "a100": SimEnv("a100", 24.0, 0.75, 40.0),
}

# dataset -> (zipf skew multiplier, overlap) — code tasks are more skewed
# (Fig. 9: HumanEval benefits most).
DATASETS = {
    "humaneval": (1.15, 0.78),
    "bigbench": (0.95, 0.70),
    "wikitext103": (0.85, 0.66),
    "mmlu_pro": (1.00, 0.72),
}

# Per-task submission and stream-synchronization overheads.  These are large
# in Transformers/PyTorch offloading stacks (allocator + python + cudaStream
# sync per expert — cf. Hobbit [37]: 336 MB expert = 10.5 ms theoretical PCIe
# vs ~14 ms measured, plus multi-ms per-call sync); batched I/O (§3.3) exists
# precisely to amortize them.
IO_LAUNCH_OVERHEAD = 1.5e-3    # per I/O task submission overhead (s)
SYNC_OVERHEAD = 8.0e-3         # per-task stream-sync stall, unbatched path (s)
CACHE_MEM_FRACTION = 0.45      # device memory share usable as expert cache
MI_BUDGET_FRACTION = 0.60      # MoE-Infinity prefetch budget (of cache slots)
CUTOFF_CACHE_FRACTION = 0.75   # SP-MoE: prefetch footprint cap (of slots)


@dataclass
class SimConfig:
    policy: str = "spmoe"      # spmoe | adapmoe | moe-infinity | on-demand
    draft_len: int = 1
    cutoff: Optional[int] = None       # None -> analytical solve
    cache_experts: Optional[int] = None  # slots; None -> from memory budget
    gpu_mem_gb: Optional[float] = None
    batched_io: bool = True
    worker_prefetch: bool = True       # False -> vanilla (sync) prefetch
    drafting_prefetch: bool = True     # False -> disable SP-MoE draft-stage PF
    seed: int = 0
    dataset: str = "humaneval"
    out_tokens: int = 100
    sd_enabled: bool = True


@dataclass
class SimResult:
    tpot: float
    hit_rate: float
    io_time: float
    compute_time: float
    draft_time: float
    evictions: int
    prefetched: int
    prefetch_wasted: int
    cutoff: int
    acceptance: float
    tokens: int


class _LRU:
    """LRU of (layer, expert) keys.

    * ``per_layer=True``   fixed per-layer rings (Mixtral-Offloading's design)
    * ``reserved_per_layer=r``  SP-MoE's stabilized caching (§3.2: "we reserve
      a fixed number of experts per layer"): each layer owns ``r`` protected
      slots for prefetched experts; the remainder is a global LRU pool.
    """

    def __init__(self, slots: int, num_layers: int, per_layer: bool = False,
                 reserved_per_layer: int = 0, reserved_layers: int = 0):
        self.per_layer = per_layer
        self.num_layers = num_layers
        self.slots = slots
        self.layer_slots = max(1, slots // num_layers)
        self.od: "OrderedDict[Tuple[int,int], int]" = OrderedDict()
        self.per_layer_od: List[OrderedDict] = [OrderedDict()
                                                for _ in range(num_layers)]
        self.reserved = reserved_per_layer
        # rings are physical: they cannot overcommit the slot pool.  Layers
        # past ring_layers get no protection — their prefetches land in the
        # (small) pool and thrash it (Fig. 3: eviction rate vs prefetch depth)
        self.ring_layers = (min(reserved_layers,
                                int(slots * 0.9) // max(reserved_per_layer, 1))
                            if reserved_per_layer else 0)
        self.reserved_layers = reserved_layers
        self.pool_slots = max(1, slots - self.reserved * self.ring_layers)
        self.pinned: set = set()
        self.evictions = 0
        self.wasted = 0

    def pin(self, key):
        self.pinned.add(key)

    def unpin_all(self):
        self.pinned.clear()

    def __contains__(self, key):
        if self.per_layer:
            return key[1] in self.per_layer_od[key[0]]
        return key in self.od or (self.reserved and
                                  key[1] in self.per_layer_od[key[0]])

    def __len__(self):
        n = sum(len(od) for od in self.per_layer_od)
        return n + len(self.od)

    def touch(self, key):
        if (self.per_layer or self.reserved) and key[1] in self.per_layer_od[key[0]]:
            od = self.per_layer_od[key[0]]
            od.move_to_end(key[1])
            od[key[1]] = 1
            return
        if key in self.od:
            self.od.move_to_end(key)
            self.od[key] = 1

    def insert(self, key, used=0, protected=False):
        """protected=True -> into the layer's reserved ring (prefetches)."""
        if key in self:
            self.touch(key)
            return
        if self.per_layer or (protected and self.reserved
                              and key[0] < self.ring_layers):
            od = self.per_layer_od[key[0]]
            cap = self.layer_slots if self.per_layer else self.reserved
            if len(od) >= cap:
                _, u = od.popitem(last=False)
                self.evictions += 1
                if not u:
                    self.wasted += 1
            od[key[1]] = used
            return
        # global pool; eviction skips pinned entries (MoE-Infinity hot set)
        while len(self.od) >= self.pool_slots:
            for cand in self.od:
                if cand not in self.pinned:
                    u = self.od.pop(cand)
                    self.evictions += 1
                    if not u:
                        self.wasted += 1
                    break
            else:
                break                         # everything pinned: overflow
        self.od[key] = used


class Simulator:
    def __init__(self, model: SimModel, env: SimEnv, sim: SimConfig):
        self.m, self.env, self.cfg = model, env, sim
        self.rng = np.random.default_rng(sim.seed)
        zipf_mult, overlap = DATASETS[sim.dataset]
        ranks = np.arange(1, model.num_experts + 1, dtype=np.float64)
        base = ranks ** (-model.zipf_a * zipf_mult)
        self.popularity = np.stack([
            self.rng.permutation(base / base.sum())
            for _ in range(model.num_layers)])
        self.overlap = overlap
        self.t_io = model.expert_mb * 1e-3 / env.pcie_gbps   # s per expert
        self.t_attn = model.t_comp_attn * env.compute_scale
        self.t_exp = model.t_comp_expert * env.compute_scale
        self.t_draft = model.t_comp_draft_layer * env.compute_scale
        mem = sim.gpu_mem_gb if sim.gpu_mem_gb is not None else env.gpu_mem_gb
        if sim.cache_experts is not None:
            slots = sim.cache_experts
        else:
            free = max(mem - model.non_expert_gb, 0.5) * CACHE_MEM_FRACTION
            slots = int(max(model.top_k, free * 1024 / model.expert_mb))
        slots = min(slots, model.num_layers * model.num_experts)
        self.slots = slots
        cutoff = sim.cutoff if sim.cutoff is not None else self._auto_cutoff()
        self.cutoff = cutoff
        # SP-MoE reserves k slots per prefetched layer (cache stabilization);
        # on-demand (Mixtral-Offloading) uses fixed per-layer rings.
        self.lru = _LRU(
            slots, model.num_layers,
            per_layer=(sim.policy == "on-demand"),
            reserved_per_layer=(model.k_prefetch
                                if sim.policy == "spmoe" else 0),
            reserved_layers=(cutoff + 1 if sim.policy == "spmoe" else 0))
        self.arrival: Dict[Tuple[int, int], float] = {}
        self.prev_pick: Dict[int, np.ndarray] = {}
        self.pending: Dict[int, np.ndarray] = {}
        self.history = np.zeros((model.num_layers, model.num_experts))
        self.hits = 0
        self.lookups = 0

    # ----------------------------------------------------- activation sampling
    def _sample_tokens(self, layer: int, n_tokens: int) -> np.ndarray:
        """[n_tokens, top_k] expert picks with neighbouring-token overlap."""
        m = self.m
        out = np.zeros((n_tokens, m.top_k), np.int64)
        prev = self.prev_pick.get(layer)
        for t in range(n_tokens):
            if prev is not None and self.rng.random() < self.overlap:
                pick = prev
            else:
                pick = self.rng.choice(m.num_experts, size=m.top_k,
                                       replace=False, p=self.popularity[layer])
            out[t] = pick
            prev = pick
        self.prev_pick[layer] = prev
        self.history[layer][np.unique(out)] += 1
        return out

    def _predict(self, layer: int, actual_block: np.ndarray) -> List[int]:
        """Cross-model prediction of the block's critical experts."""
        m = self.m
        crit = list(dict.fromkeys(actual_block.ravel().tolist()))[: m.k_prefetch]
        preds = []
        for e in crit:
            if self.rng.random() < m.predictor_acc:
                preds.append(int(e))
            else:
                p = self.popularity[layer].copy()
                p[np.unique(actual_block)] = 0
                s = p.sum()
                preds.append(int(self.rng.choice(m.num_experts, p=p / s))
                             if s > 0 else int(e))
        return list(dict.fromkeys(preds))

    # --------------------------------------------------------------- helpers
    def _resident(self, layer: int, e: int) -> bool:
        # (shared experts are always device-resident and never sampled here:
        # lookups cover the ROUTED experts only)
        return (layer, int(e)) in self.lru

    def _io(self, n_experts: int, n_tasks: int, sync: bool = False) -> float:
        dur = n_experts * self.t_io + n_tasks * IO_LAUNCH_OVERHEAD
        if sync:
            # stream-sync stall grows with transfer size (alloc + copy split);
            # floor for tiny experts
            dur += n_tasks * max(2.0e-3, SYNC_OVERHEAD * self.m.expert_mb / 336.0)
        return dur

    # ------------------------------------------------------------------- run
    #
    # I/O is modeled as a single link with TWO priorities: on-demand loads
    # are urgent and preempt queued background prefetch; background segments
    # (each = one batched prefetch task, tagged with the layer it serves)
    # drain whenever the link would otherwise idle, and are force-drained
    # before their layer's verification (they count as hits with a
    # just-in-time arrival wait).  This matches the asynchronous worker +
    # dedicated transfer stream of §3.3.
    def _drain_background(self, upto_layer: int, now: float) -> None:
        """Run background segments that must complete (layer <= upto_layer)
        or that would have started in link-idle time before `now`."""
        while self._bg:
            seg_layer, dur, keys, issue_at = self._bg[0]
            start = max(self._io_done, issue_at)
            if seg_layer <= upto_layer or start < now:
                self._io_done = start + dur
                for k in keys:
                    self.arrival[k] = self._io_done
                self._bg.pop(0)
            else:
                break

    def _bg_submit(self, layer: int, dur: float, keys, issue_at: float):
        self._bg.append((layer, dur, keys, issue_at))

    def run(self) -> SimResult:
        m, cfg = self.m, self.cfg
        N = cfg.draft_len if cfg.sd_enabled else 0
        cutoff = self.cutoff
        now = 0.0
        self._io_done = 0.0          # link busy until (absolute)
        self._bg: List[tuple] = []   # background prefetch segments
        io_time = compute_time = draft_time = 0.0
        prefetched = 0
        tokens_out = 0
        # Fig. 2b's overlap is WITHIN a draft block (neighbouring tokens);
        # across iterations the activation pattern drifts much harder, which
        # is what keeps purely-reactive caches (MO/MI) at ~15% hit (Table 3).
        drift = 0.85
        while tokens_out < cfg.out_tokens:
            for l in list(self.prev_pick.keys()):
                if self.rng.random() < drift:
                    self.prev_pick.pop(l)
            # ---------------- drafting stage ----------------
            draft_dur = N * m.num_layers * self.t_draft
            if cfg.sd_enabled and cfg.policy == "spmoe" and cfg.drafting_prefetch:
                for l in range(min(cutoff + 1, m.num_layers)):
                    block = self._sample_tokens(l, N + 1)
                    self.pending[l] = block
                    preds = self._predict(l, block)
                    new = [e for e in preds if not self._resident(l, e)]
                    if not new:
                        continue
                    # task issued when draft layer l completes (Algorithm 1)
                    issue_at = now + (l / max(m.num_layers, 1)) * draft_dur
                    dur = self._io(len(new), 1 if cfg.batched_io else len(new),
                                   sync=not cfg.worker_prefetch)
                    if not cfg.worker_prefetch:
                        draft_dur += dur          # vanilla PF blocks compute
                    io_time += dur
                    prefetched += len(new)
                    keys = [(l, e) for e in new]
                    for e in new:
                        self.lru.insert((l, e), used=0, protected=True)
                    self._bg_submit(l, dur, keys, issue_at)
            elif cfg.policy == "moe-infinity":
                # request-level, history-ranked, budget-capped prefetch;
                # depth-unbounded greedy tasks (Observation II)
                budget = min(int(self.lru.slots * MI_BUDGET_FRACTION),
                             m.num_layers * m.k_prefetch)
                score = self.history + self.popularity      # [L, E]
                order = np.dstack(np.unravel_index(
                    np.argsort(-score, axis=None), score.shape))[0]
                todo = []
                self.lru.unpin_all()
                for l, e in order[:budget]:
                    key = (int(l), int(e))
                    self.lru.pin(key)         # hot set stays resident
                    if not self._resident(int(l), int(e)):
                        todo.append(key)
                if todo:
                    # greedy per-layer tasks (Observation II: excessive task
                    # generation, no batching across layers)
                    n_tasks = len({k[0] for k in todo})
                    dur = self._io(len(todo), n_tasks)
                    io_time += dur
                    prefetched += len(todo)
                    for key in todo:
                        self.lru.insert(key, used=0)
                    # MoE-Infinity is SD-agnostic: tasks are not layer-phased,
                    # so they sit ahead of on-demand traffic (layer -1 =
                    # drain before anything else -> bandwidth contention).
                    self._bg_submit(-1, dur, todo, now)
                    self._drain_background(-1, now)
            now += draft_dur
            draft_time += draft_dur
            # ---------------- verification stage ----------------
            for l in range(m.num_layers):
                block = self.pending.pop(l, None)
                if block is None:
                    block = self._sample_tokens(l, N + 1)
                now += self.t_attn
                compute_time += self.t_attn
                # background prefetch for this layer must land; idle-time
                # segments for deeper layers drain opportunistically
                self._drain_background(l, now)
                # lookups: unique activated experts, resident-before-block
                uniq = list(dict.fromkeys(block.ravel().tolist()))
                missing: List[int] = []
                wait_until = now
                for e in uniq:
                    self.lookups += 1
                    if self._resident(l, int(e)):
                        self.hits += 1
                        self.lru.touch((l, int(e)))
                        wait_until = max(wait_until,
                                         self.arrival.pop((l, int(e)), now))
                    else:
                        missing.append(int(e))
                now = wait_until                 # just-in-time arrival wait
                if missing:                      # on-demand: urgent priority
                    if cfg.policy == "on-demand":
                        # vanilla offloading: per-expert synchronous copies
                        dur = self._io(len(missing), len(missing), sync=True)
                    else:
                        dur = self._io(len(missing),
                                       1 if cfg.batched_io else len(missing))
                    start = max(now, self._io_done)
                    self._io_done = start + dur
                    io_time += dur
                    now = self._io_done          # FFN waits for its weights
                    for e in missing:
                        self.lru.insert((l, e), used=1)
                # AdapMoE: gate of layer l predicts ONE expert of l+1,
                # prefetched while this layer's FFN computes; the stream sync
                # stalls at the l+1 boundary if unfinished (§3.3, Fig. 8)
                if cfg.policy == "adapmoe" and l + 1 < m.num_layers:
                    blk_next = self._sample_tokens(l + 1, N + 1)
                    self.pending[l + 1] = blk_next
                    preds = self._predict(l + 1, blk_next)[:1]
                    new = [e for e in preds if not self._resident(l + 1, e)]
                    if new:
                        dur = self._io(len(new), len(new))
                        io_time += dur
                        prefetched += len(new)
                        for e in new:
                            self.lru.insert((l + 1, e), used=0)
                        self._bg_submit(l + 1, dur, [(l + 1, e) for e in new],
                                        now)
                        now += 2.0e-3          # stream sync at layer boundary
                exp_t = len(uniq) * self.t_exp
                now += exp_t
                compute_time += exp_t
            self._drain_background(m.num_layers, now)   # finish leftovers
            # ---------------- acceptance ----------------
            if cfg.sd_enabled and N > 0:
                n_acc = int(np.sum(np.cumprod(
                    self.rng.random(N) < m.acceptance)))
                tokens_out += n_acc + 1
            else:
                tokens_out += 1
        return SimResult(
            tpot=now / max(tokens_out, 1),
            hit_rate=self.hits / max(self.lookups, 1),
            io_time=io_time, compute_time=compute_time, draft_time=draft_time,
            evictions=self.lru.evictions, prefetched=prefetched,
            prefetch_wasted=self.lru.wasted, cutoff=cutoff,
            acceptance=self.m.acceptance, tokens=tokens_out)

    def _auto_cutoff(self) -> int:
        """Cache-pressure-bounded cutoff: prefetching deeper than the cache
        can hold causes eviction thrash (Observation II / Fig. 3), so cap the
        prefetch footprint to a fraction of the slots.  With in-order I/O the
        just-in-time constraint is dominated by this capacity bound (§3.2
        discussion; matches the empirical optimum of Fig. 14)."""
        m = self.m
        by_mem = int(CUTOFF_CACHE_FRACTION * self.slots / m.k_prefetch) - 1
        return max(0, min(m.num_layers - 1, by_mem))


def simulate(model_name: str, env_name: str = "4090", **overrides) -> SimResult:
    cfg = SimConfig(**overrides)
    return Simulator(SIM_MODELS[model_name], ENVS[env_name], cfg).run()
