"""Pipelined prefetch runtime (paper §3.3, Algorithm 2).

A dedicated worker thread drains a prefetching task queue and executes
batched loads into the ExpertCache.  Each task carries an "enqueue complete"
event (the cuda.Event analogue — here a threading.Event resolved by the
producer) so the worker never consumes half-prepared task descriptors, and a
"done" event the compute loop can wait on for just-in-time arrival.

Two executor flavours mirror the paper's ablation (Figure 8/12):

* ``vanilla``  layer-triggered, synchronous: the producer thread itself loads
               and blocks (I/O serializes with compute).
* ``worker``   continuous background prefetching on the worker thread; with
               ``batched=True`` all experts of a task are loaded in one
               transfer (batched I/O), otherwise one transfer per expert.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.cache import ExpertCache, ExpertKey
from repro.core.offload import HostExpertStore


@dataclass
class PrefetchTask:
    keys: List[ExpertKey]
    ready: threading.Event                 # producer-side enqueue checkpoint
    done: threading.Event = field(default_factory=threading.Event)
    cancelled: bool = False


class Prefetcher:
    def __init__(self, store: HostExpertStore, cache: ExpertCache,
                 mode: str = "worker", batched: bool = True):
        assert mode in ("vanilla", "worker", "off")
        self.store = store
        self.cache = cache
        self.mode = mode
        self.batched = batched
        self.queue: "queue.Queue[Optional[PrefetchTask]]" = queue.Queue()
        self.loaded_count = 0
        self.io_events: List[int] = []     # batch sizes, for kernel-launch accounting
        self._thread: Optional[threading.Thread] = None
        if mode == "worker":
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    # ---------------------------------------------------------------- produce
    def submit(self, keys: Sequence[ExpertKey]) -> Optional[PrefetchTask]:
        """Predictor-side enqueue (Algorithm 1 lines 7-8).  Cached experts are
        skipped by the caller via cache.lookup(touch=False)."""
        if self.mode == "off" or not keys:
            return None
        task = PrefetchTask(keys=list(keys), ready=threading.Event())
        task.ready.set()                   # descriptor fully prepared
        if self.mode == "vanilla":
            self._execute(task)            # synchronous: blocks the producer
        else:
            self.queue.put(task)
        return task

    # ---------------------------------------------------------------- consume
    def _run(self):
        while True:
            task = self.queue.get()
            if task is None:
                return
            task.ready.wait()              # Algorithm 2 line 5
            if not task.cancelled:
                self._execute(task)
            task.done.set()

    def _execute(self, task: PrefetchTask):
        keys = [k for k in task.keys if not self.cache.contains(k)]
        if not keys:
            task.done.set()
            return
        if self.batched:
            arrays = self.store.fetch(keys)
            self.cache.insert(keys, arrays)          # one transfer + scatter
            self.io_events.append(len(keys))
        else:
            for k in keys:                            # per-expert sync I/O
                arrays = self.store.fetch([k])
                self.cache.insert([k], arrays)
                self.io_events.append(1)
        self.loaded_count += len(keys)
        task.done.set()

    # ------------------------------------------------------------------ admin
    def drain(self):
        """Block until the queue is empty and transfers have landed."""
        self.queue.join() if False else None
        while not self.queue.empty():
            import time
            time.sleep(0.001)
        self.cache.wait()

    def stop(self):
        if self._thread is not None:
            self.queue.put(None)
            self._thread.join(timeout=5)
            self._thread = None

    def __del__(self):
        try:
            self.stop()
        except Exception:
            pass
