"""Pipelined prefetch runtime (paper §3.3, Algorithm 2).

A dedicated worker thread drains a prefetching task queue and executes
batched loads into the ExpertCache.  Each task carries an "enqueue complete"
event (the cuda.Event analogue — here a threading.Event resolved by the
producer) so the worker never consumes half-prepared task descriptors, and a
"done" event the compute loop can wait on for just-in-time arrival.

In-flight accounting is a counter + condition variable: ``submit`` increments
before enqueueing, the worker decrements after the task is fully executed
(including the cache insert dispatch), and ``drain()`` waits on the condition
— no polling, and no window where a popped-but-still-executing task escapes
the barrier.  The store's double-buffered staging plus the cache's
non-blocking insert mean the worker's H2D transfer for task *i* overlaps the
host gather for task *i+1*.

Two executor flavours mirror the paper's ablation (Figure 8/12):

* ``vanilla``  layer-triggered, synchronous: the producer thread itself loads
               and blocks (I/O serializes with compute).
* ``worker``   continuous background prefetching on the worker thread; with
               ``batched=True`` all experts of a task are loaded in one
               transfer (batched I/O), otherwise one transfer per expert.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.cache import ExpertCache, ExpertKey
from repro.core.offload import HostExpertStore


@dataclass
class PrefetchTask:
    keys: List[ExpertKey]
    ready: threading.Event                 # producer-side enqueue checkpoint
    done: threading.Event = field(default_factory=threading.Event)
    cancelled: bool = False
    # per-task I/O attribution (prefetched / evictions /
    # prefetch_evicted_unused), filled by the executing thread; the session
    # that submitted the task folds it at retirement — after done.wait(), so
    # the Event publishes the writes.  This is what keeps per-request I/O
    # ledgers exact when a load lands between two sessions' interleaved
    # turns (it belongs to the task's owner, not to whoever's turn it was).
    stats: Dict[str, int] = field(default_factory=dict)


class Prefetcher:
    def __init__(self, store: HostExpertStore, cache: ExpertCache,
                 mode: str = "worker", batched: bool = True):
        assert mode in ("vanilla", "worker", "off")
        self.store = store
        self.cache = cache
        self.mode = mode
        self.batched = batched
        self.queue: "queue.Queue[Optional[PrefetchTask]]" = queue.Queue()
        self.loaded_count = 0
        self.io_events: List[int] = []     # batch sizes, for kernel-launch accounting
        self._cv = threading.Condition()
        self._inflight = 0                 # submitted but not yet executed
        self.errors: List[BaseException] = []   # surfaced worker failures
        self._thread: Optional[threading.Thread] = None
        if mode == "worker":
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    # ---------------------------------------------------------------- produce
    def submit(self, keys: Sequence[ExpertKey]) -> Optional[PrefetchTask]:
        """Predictor-side enqueue (Algorithm 1 lines 7-8).  Cached experts are
        skipped by the caller via cache.lookup(touch=False)."""
        if self.mode == "off" or not keys:
            return None
        task = PrefetchTask(keys=list(keys), ready=threading.Event())
        task.ready.set()                   # descriptor fully prepared
        if self.mode == "vanilla":
            self._execute(task)            # synchronous: blocks the producer
            task.done.set()
        elif self._thread is None or not self._thread.is_alive():
            # submit after stop() (or with a dead worker): enqueueing would
            # bump _inflight with nothing left to decrement it, hanging
            # drain() forever — degrade to synchronous execution instead
            self._execute(task)
            task.done.set()
        else:
            with self._cv:
                self._inflight += 1
            self.queue.put(task)
        return task

    # ---------------------------------------------------------------- consume
    def _run(self):
        while True:
            task = self.queue.get()
            if task is None:
                self.queue.task_done()
                return
            try:
                task.ready.wait()          # Algorithm 2 line 5
                if not task.cancelled:
                    self._execute(task)
            except BaseException as e:     # keep the worker alive: a failed
                self.errors.append(e)      # task must not strand the queue
            finally:
                task.done.set()
                self.queue.task_done()
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _execute(self, task: PrefetchTask):
        keys = [k for k in task.keys if not self.cache.contains(k)]
        if not keys:
            return
        if self.batched:
            arrays = self.store.fetch(keys)
            self.cache.insert_async(keys, arrays,    # one transfer + scatter
                                    stats=task.stats)
            self.io_events.append(len(keys))
        else:
            for k in keys:                            # per-expert sync I/O
                arrays = self.store.fetch([k])
                self.cache.insert_async([k], arrays, stats=task.stats)
                self.io_events.append(1)
        self.loaded_count += len(keys)
        task.stats["prefetched"] = task.stats.get("prefetched", 0) + len(keys)

    # ------------------------------------------------------------------ admin
    def reset_stats(self):
        """Zero the I/O accounting (loaded_count / io_events).  Owned here so
        the engine's reset doesn't poke prefetcher internals; in-flight task
        state is untouched — call ``drain()`` first for a clean cut."""
        self.loaded_count = 0
        self.io_events = []

    def drain(self):
        """Block until every submitted task has fully executed and the device
        transfers have landed.  Condition-variable wait — no busy-wait, and a
        task popped from the queue but still mid-``_execute`` is covered by
        the in-flight counter."""
        if self.mode == "worker":
            with self._cv:
                self._cv.wait_for(lambda: self._inflight == 0)
        self.cache.wait()

    def stop(self):
        if self._thread is not None:
            self.queue.put(None)
            self._thread.join(timeout=5)
            self._thread = None

    def __del__(self):
        try:
            self.stop()
        except Exception:
            pass
