"""Pipelined prefetch runtime (paper §3.3, Algorithm 2) — supervised.

A dedicated worker thread drains a prefetching task queue and executes
batched loads into the ExpertCache.  Each task carries an "enqueue complete"
event (the cuda.Event analogue — here a threading.Event resolved by the
producer) so the worker never consumes half-prepared task descriptors, and a
"done" event the compute loop can wait on for just-in-time arrival.

In-flight accounting is a counter + condition variable: ``submit`` increments
before enqueueing, the worker decrements after the task is fully executed
(including the cache insert dispatch), and ``drain()`` waits on the condition
— no polling, and no window where a popped-but-still-executing task escapes
the barrier.  The store's double-buffered staging plus the cache's
non-blocking insert mean the worker's H2D transfer for task *i* overlaps the
host gather for task *i+1*.

Two executor flavours mirror the paper's ablation (Figure 8/12):

* ``vanilla``  layer-triggered, synchronous: the producer thread itself loads
               and blocks (I/O serializes with compute).
* ``worker``   continuous background prefetching on the worker thread; with
               ``batched=True`` all experts of a task are loaded in one
               transfer (batched I/O), otherwise one transfer per expert.

Resilience plane (the serving analogue of ``runtime.fault_tolerance``)
----------------------------------------------------------------------
The I/O channel is treated as *fallible in fact*, not just in latency:

* **retry with backoff** — a task's fetch/insert is retried up to
  ``retries`` times with exponential backoff on transient I/O errors
  (:class:`~repro.core.chaos.ChaosError` / ``OSError``), including checksum
  mismatches when ``verify=True`` (corrupt payloads are quarantined — never
  inserted — and refetched);
* **per-task deadlines** — ``task_timeout_s`` stamps each task with a
  deadline; an expired task is failed instead of retried forever;
* **supervised worker** — the worker beats a
  :class:`~repro.runtime.fault_tolerance.Heartbeat` every loop; a dead
  worker (e.g. chaos ``kill_worker_every``) hands its task back to the
  queue before exiting, so ``_inflight`` never strands, and
  :meth:`revive` restarts it (bounded by ``max_worker_restarts``) — once
  the budget is spent, pending tasks are released via
  :meth:`abandon_pending` and the prefetch plane reports unhealthy;
* **circuit breaker** — ``fail_threshold`` consecutive task failures open
  the breaker for ``cooloff_s`` (:meth:`healthy` returns False; the engine
  degrades to on-demand loading) and it half-opens after the cooloff so
  health recovers when the fault clears;
* **bounded waits** — ``drain(timeout=)`` and :meth:`wait_task` return
  False instead of hanging, and both pump :meth:`revive` so a task stuck
  behind a dead worker is restarted or abandoned rather than waited on
  forever;
* **bounded error memory** — failures land in an ``errors`` ring (last
  ``error_ring``) plus a monotonic ``error_count``, surfaced through
  ``OffloadEngine.counters()`` — no unbounded growth, no silent loss.

Every fault path keeps the core invariant: a submitted task's ``done``
event is ALWAYS eventually set (success, failure, timeout or abandonment),
so ``finish_session``'s per-task waits and ``drain`` barriers stay bounded.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.core.cache import ExpertCache, ExpertKey
from repro.core.chaos import ChaosError, ChaosInjector, PayloadCorruption
from repro.core.offload import HostExpertStore
from repro.runtime.fault_tolerance import Heartbeat

# transient I/O faults worth retrying (ChaosError subclasses IOError/OSError)
TRANSIENT_IO = (OSError,)


@dataclass
class PrefetchTask:
    keys: List[ExpertKey]
    ready: threading.Event                 # producer-side enqueue checkpoint
    done: threading.Event = field(default_factory=threading.Event)
    cancelled: bool = False
    deadline: Optional[float] = None       # monotonic; None = no deadline
    attempts: int = 0                      # execution attempts consumed
    failed: Optional[BaseException] = None # terminal failure, if any
    # per-task I/O attribution (prefetched / evictions /
    # prefetch_evicted_unused), filled by the executing thread; the session
    # that submitted the task folds it at retirement — after done.wait(), so
    # the Event publishes the writes.  This is what keeps per-request I/O
    # ledgers exact when a load lands between two sessions' interleaved
    # turns (it belongs to the task's owner, not to whoever's turn it was).
    stats: Dict[str, int] = field(default_factory=dict)

    def expired(self) -> bool:
        return self.deadline is not None and time.monotonic() > self.deadline


class Prefetcher:
    def __init__(self, store: HostExpertStore, cache: ExpertCache,
                 mode: str = "worker", batched: bool = True, *,
                 retries: int = 3, backoff_s: float = 0.002,
                 task_timeout_s: Optional[float] = None,
                 verify: bool = False,
                 heartbeat_timeout_s: float = 10.0,
                 max_worker_restarts: int = 3,
                 fail_threshold: int = 3, cooloff_s: float = 0.25,
                 error_ring: int = 64,
                 chaos: Optional[ChaosInjector] = None):
        assert mode in ("vanilla", "worker", "off")
        self.store = store
        self.cache = cache
        self.mode = mode
        self.batched = batched
        self.retries = max(0, retries)
        self.backoff_s = backoff_s
        self.task_timeout_s = task_timeout_s
        self.verify = verify
        self.max_worker_restarts = max_worker_restarts
        self.fail_threshold = fail_threshold
        self.cooloff_s = cooloff_s
        self.chaos = chaos
        self.queue: "queue.Queue[Optional[PrefetchTask]]" = queue.Queue()
        self.loaded_count = 0
        self.io_events: List[int] = []     # batch sizes, for kernel-launch accounting
        self._cv = threading.Condition()
        self._inflight = 0                 # submitted but not yet executed
        # bounded error memory: ring of the last ``error_ring`` failures plus
        # a monotonic count (the ring is for debugging, the count for the
        # metrics plane — callers consult counters(), not the ring)
        self.errors: Deque[BaseException] = deque(maxlen=error_ring)
        self.error_count = 0
        self.retry_count = 0
        self.checksum_refetches = 0        # corrupt payloads quarantined+refetched
        self.worker_restarts = 0
        self.worker_deaths = 0
        self.drain_timeouts = 0
        self.refused_submits = 0
        self.abandoned_tasks = 0
        self.consecutive_failures = 0
        self._last_failure_t = 0.0
        self._stopped = False
        self.heartbeat = Heartbeat(host_id=0, timeout_s=heartbeat_timeout_s) \
            if mode == "worker" else None
        self._thread: Optional[threading.Thread] = None
        if mode == "worker":
            self._start_worker()

    # ---------------------------------------------------------------- produce
    def submit(self, keys: Sequence[ExpertKey]) -> Optional[PrefetchTask]:
        """Predictor-side enqueue (Algorithm 1 lines 7-8).  Cached experts are
        skipped by the caller via cache.lookup(touch=False).

        Degradation order when the worker plane is unavailable: a confirmed-
        dead worker is restarted (bounded); past the restart budget — or
        after a clean ``stop()`` — the task executes inline (synchronous
        prefetch); after a ``stop()`` whose join TIMED OUT the worker may
        still be alive and wedged on this very queue/cache, so new submits
        are REFUSED (returns None) rather than raced against it."""
        if self.mode == "off" or not keys:
            return None
        task = PrefetchTask(keys=list(keys), ready=threading.Event())
        if self.task_timeout_s is not None:
            task.deadline = time.monotonic() + self.task_timeout_s
        task.ready.set()                   # descriptor fully prepared
        if self.mode == "vanilla":
            self._run_inline(task)         # synchronous: blocks the producer
            return task
        if self._stopped:
            t = self._thread
            if t is not None and t.is_alive():
                # stop() join timed out: a wedged worker may wake up and
                # race an inline execution on the same queue/cache — refuse
                self.refused_submits += 1
                return None
            self._run_inline(task)         # confirmed dead: degrade inline
            return task
        if not self._ensure_worker():
            # restart budget exhausted: degrade to synchronous execution —
            # enqueueing would bump _inflight with nothing left to
            # decrement it, hanging drain() forever
            self._run_inline(task)
            return task
        with self._cv:
            self._inflight += 1
        self.queue.put(task)
        return task

    # ---------------------------------------------------------------- consume
    def _start_worker(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _ensure_worker(self) -> bool:
        """True iff a live worker is available, restarting a dead one while
        the ``max_worker_restarts`` budget lasts.  Never resurrects a worker
        after ``stop()``."""
        if self.mode != "worker" or self._stopped:
            return False
        t = self._thread
        if t is not None and t.is_alive():
            return True
        if self.worker_restarts >= self.max_worker_restarts:
            return False
        self.worker_restarts += 1
        self._start_worker()
        return True

    def _run(self):
        hb = self.heartbeat
        while True:
            try:
                task = self.queue.get(timeout=0.1)
            except queue.Empty:
                if hb:
                    hb.beat()              # idle liveness
                continue
            if hb:
                hb.beat()
            if task is None:
                self.queue.task_done()
                return
            if self.chaos is not None and self.chaos.should_kill_worker():
                # simulated crash: hand the task back untouched so the
                # in-flight accounting survives the death — the supervisor
                # (revive / _ensure_worker) restarts us and the task is
                # simply executed later, out of order but order-insensitive
                self.worker_deaths += 1
                self.queue.put(task)
                self.queue.task_done()
                return
            try:
                task.ready.wait(timeout=5.0)   # Algorithm 2 line 5
                if not task.cancelled:
                    self._execute_with_retry(task)
            except BaseException as e:     # keep the worker alive: a failed
                self._record_failure(task, e)  # task must not strand the queue
            finally:
                if hb:
                    hb.beat()
                task.done.set()
                self.queue.task_done()
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _run_inline(self, task: PrefetchTask):
        """Synchronous execution on the producer thread (vanilla mode and
        worker-plane degradation).  Prefetch is best-effort: failures are
        recorded, never raised to the producer — a missed prefetch is
        resolved by the slow path's on-demand loads."""
        try:
            if not task.cancelled:
                self._execute_with_retry(task)
        except BaseException as e:
            self._record_failure(task, e)
        finally:
            task.done.set()

    def _record_failure(self, task: PrefetchTask, e: BaseException):
        task.failed = e
        self.errors.append(e)
        self.error_count += 1
        self.consecutive_failures += 1
        self._last_failure_t = time.monotonic()

    def _execute_with_retry(self, task: PrefetchTask):
        """Bounded retry-with-backoff around ``_execute``: transient I/O
        faults (including checksum mismatches — the corrupt payload is never
        inserted, just refetched) consume the ``retries`` budget; a task
        past its deadline stops retrying immediately.  Success resets the
        circuit-breaker streak."""
        attempts = self.retries + 1
        last: Optional[BaseException] = None
        for a in range(attempts):
            if task.expired():
                raise last if last is not None else \
                    TimeoutError(f"prefetch task deadline expired "
                                 f"({len(task.keys)} keys)")
            task.attempts += 1
            try:
                self._execute(task)
                self.consecutive_failures = 0
                return
            except PayloadCorruption as e:
                self.checksum_refetches += 1
                last = e
            except TRANSIENT_IO as e:
                last = e
            if a < attempts - 1:
                self.retry_count += 1
                time.sleep(self.backoff_s * (2 ** a))
        raise last

    def _fetch(self, keys: Sequence[ExpertKey]):
        if self.verify:
            return self.store.fetch_verified(keys)
        return self.store.fetch(keys)

    def _execute(self, task: PrefetchTask):
        keys = [k for k in task.keys if not self.cache.contains(k)]
        if not keys:
            return
        if self.batched:
            arrays = self._fetch(keys)
            self.cache.insert_async(keys, arrays,    # one transfer + scatter
                                    stats=task.stats)
            self.io_events.append(len(keys))
        else:
            for k in keys:                            # per-expert sync I/O
                arrays = self._fetch([k])
                self.cache.insert_async([k], arrays, stats=task.stats)
                self.io_events.append(1)
        self.loaded_count += len(keys)
        task.stats["prefetched"] = task.stats.get("prefetched", 0) + len(keys)

    # ------------------------------------------------------------------ health
    def worker_alive(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def worker_wedged(self) -> bool:
        """A live worker whose heartbeat went stale while work is pending —
        stuck inside a transfer (e.g. a pathological latency spike)."""
        if self.heartbeat is None or not self.worker_alive():
            return False
        with self._cv:
            pending = self._inflight > 0
        return pending and not self.heartbeat.alive()

    def breaker_open(self) -> bool:
        """Circuit breaker: ``fail_threshold`` consecutive task failures
        open it for ``cooloff_s``; it half-opens after the cooloff so a
        cleared fault lets health recover."""
        return (self.consecutive_failures >= self.fail_threshold
                and (time.monotonic() - self._last_failure_t) < self.cooloff_s)

    def healthy(self) -> bool:
        """Is the prefetch plane trustworthy right now?  (Pure probe — use
        :meth:`revive` for the probe-and-repair step.)"""
        if self.mode == "off":
            return True
        if self.breaker_open():
            return False
        if self.mode != "worker":
            return True
        return (not self._stopped and self.worker_alive()
                and not self.worker_wedged())

    def revive(self) -> bool:
        """Probe-and-repair health step (the engine calls this once per
        scheduling round): restarts a dead worker while the budget lasts;
        once the budget is spent, releases any stranded queued tasks so no
        waiter hangs on a task nobody will execute.  Returns overall
        health."""
        if self.mode == "worker" and not self._stopped:
            if not self._ensure_worker():
                self.abandon_pending()
                return False
            if self.worker_wedged():
                return False
        return self.healthy()

    def abandon_pending(self) -> int:
        """Fail every queued (not-yet-executing) task: marks it failed, sets
        ``done`` and releases its in-flight count.  Used when the worker is
        permanently gone — a queued task must never strand its waiters."""
        n = 0
        while True:
            try:
                task = self.queue.get_nowait()
            except queue.Empty:
                return n
            self.queue.task_done()
            if task is None:
                continue
            self._record_failure(
                task, ChaosError("prefetch task abandoned: worker unavailable"))
            task.done.set()
            self.abandoned_tasks += 1
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()
            n += 1

    def wait_task(self, task: PrefetchTask, timeout: float = 30.0) -> bool:
        """Bounded wait for one task, pumping :meth:`revive` so a task stuck
        behind a dead worker is restarted-or-abandoned instead of waited on
        forever.  True = the task completed (successfully or not)."""
        deadline = time.monotonic() + timeout
        while not task.done.wait(timeout=0.05):
            if time.monotonic() > deadline:
                return False
            if self.mode == "worker" and not self._stopped:
                self.revive()
        return True

    # ------------------------------------------------------------------ admin
    def reset_stats(self):
        """Zero the I/O + error accounting.  Owned here so the engine's
        reset doesn't poke prefetcher internals; in-flight task state and
        the worker-restart BUDGET are untouched (restarts are a lifetime
        bound, not a steady-state stat) — call ``drain()`` first for a
        clean cut."""
        self.loaded_count = 0
        self.io_events = []
        self.error_count = 0
        self.retry_count = 0
        self.checksum_refetches = 0
        self.drain_timeouts = 0
        self.refused_submits = 0
        self.abandoned_tasks = 0

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted task has fully executed and the device
        transfers have landed — or until ``timeout`` (seconds) expires, in
        which case False is returned instead of hanging.  The wait pumps
        :meth:`revive`, so tasks stranded behind a dead worker are restarted
        or abandoned rather than waited on forever."""
        if self.mode == "worker":
            deadline = None if timeout is None \
                else time.monotonic() + timeout
            while True:
                with self._cv:
                    if self._inflight == 0:
                        break
                    self._cv.wait(timeout=0.05)
                    if self._inflight == 0:
                        break
                if not self._stopped:
                    self.revive()
                if deadline is not None and time.monotonic() > deadline:
                    self.drain_timeouts += 1
                    return False
        self.cache.wait()
        return True

    def stop(self, timeout: float = 5.0) -> bool:
        """Shut the worker down.  Returns True when the worker is confirmed
        stopped (pending tasks released); False when the join TIMED OUT —
        the thread handle is KEPT so a later ``stop()`` can try again, and
        ``submit`` refuses new work rather than racing the possibly-still-
        live worker on the queue/cache."""
        self._stopped = True
        t = self._thread
        if t is None:
            return True
        self.queue.put(None)               # poison pill (again, if retried)
        t.join(timeout=timeout)
        if t.is_alive():
            return False                   # keep the handle; submits refused
        self._thread = None
        self.abandon_pending()             # release anything the dead worker
        return True                        # left queued (incl. stale pills)

    def __del__(self):
        try:
            self.stop(timeout=1.0)
        except Exception:
            pass
