"""Cross-model expert predictor (paper §3.2, Algorithm 1).

During drafting, the draft model's layer-``l`` gate input (post-attention,
pre-FFN hidden state) is fed through the *target* model's layer-``l`` gating
network; the top-k scored experts are the predicted critical experts for the
upcoming verification of that layer.

Also provides the entropy analytics behind Observation I (Figure 2c): the
entropy of the predicted activation distribution under the random /
coarse-grained (MoE-Infinity) / gating-based strategies.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import ExpertKey


class ExpertPredictor:
    """Holds the target model's per-layer gate weights; scores draft taps."""

    def __init__(self, cfg: ModelConfig, target_params, k_prefetch: int):
        self.cfg = cfg
        self.k = k_prefetch
        # stacked gates of the target's MoE layers: [L_moe, d, E]
        self.gates = np.asarray(target_params["layers"]["moe"]["gate"])
        self.num_layers = self.gates.shape[0]
        self._score = jax.jit(
            lambda g, h: jax.lax.top_k(
                jax.nn.softmax(h.astype(jnp.float32) @ g, axis=-1), self.k))

    def predict_layer(self, layer: int, tap: jax.Array
                      ) -> List[ExpertKey]:
        """tap: [B, 1, d] draft gate-input for layer ``layer`` -> predicted
        critical experts of the corresponding target layer."""
        h = np.asarray(tap).reshape(-1, tap.shape[-1])
        _, ids = self._score(self.gates[layer], jnp.asarray(h))
        uniq = list(dict.fromkeys(int(i) for i in np.asarray(ids).ravel()))
        return [(layer, e) for e in uniq[: self.k]]

    def predict_all(self, taps: jax.Array, cutoff: int) -> List[ExpertKey]:
        """taps: [L, B, 1, d] (one draft step) -> predictions for layers
        0..cutoff, shallow layers first (just-in-time ordering)."""
        out: List[ExpertKey] = []
        L = min(cutoff + 1, self.num_layers, taps.shape[0])
        for l in range(L):
            out.extend(self.predict_layer(l, taps[l]))
        return out


# ---------------------------------------------------------------------------
# Observation I analytics (Figure 2)
# ---------------------------------------------------------------------------

def entropy(p: np.ndarray, axis: int = -1) -> np.ndarray:
    p = np.clip(p, 1e-12, 1.0)
    p = p / p.sum(axis=axis, keepdims=True)
    return -(p * np.log2(p)).sum(axis=axis)


def strategy_entropies(gate_probs: np.ndarray, history_counts: np.ndarray
                       ) -> Dict[str, float]:
    """gate_probs: [T, E] actual per-token gate distributions;
    history_counts: [E] historical activation counts (MoE-Infinity proxy).

    Returns mean entropy of the three prediction strategies of Fig. 2c.
    """
    T, E = gate_probs.shape
    rand = np.full((E,), 1.0 / E)
    hist = history_counts / max(history_counts.sum(), 1e-9)
    return {
        "random": float(entropy(rand)),
        "coarse_grained": float(entropy(hist)),
        "gating_based": float(entropy(gate_probs).mean()),
    }


def activation_overlap(ids_a: np.ndarray, ids_b: np.ndarray) -> float:
    """Fraction of overlap between two tokens' expert sets (Fig. 2b)."""
    a, b = set(ids_a.tolist()), set(ids_b.tolist())
    return len(a & b) / max(len(a | b), 1)
