"""Speculative decoding engine (draft-then-verify, greedy acceptance).

Semantics follow Leviathan et al. [20] with greedy (temperature-0) decoding,
matching the paper (§7: "SP-MoE adopts greedy decoding").  The engine is
LOSSLESS: the emitted sequence is bit-identical to target-only greedy
decoding — property-tested in tests/test_sd.py.

Invariant: caches hold absolute positions 0..pos-1; ``cur`` is the token at
position ``pos`` that has not been fed yet.  One iteration:

  drafting     draft model autoregressively proposes d_1..d_N from cur,
               emitting per-layer gate-input taps for the SP-MoE predictor;
  verification target runs ONE forward over the block [cur, d_1..d_N]
               (N+1 positions) and greedily accepts the longest matching
               prefix, then appends the correction/bonus token g_n.

Rejected positions leave stale cache slots; they are always overwritten by
the next iteration's block before they can be attended (the next block
starts at pos+n+1 and spans N+1 >= remaining stale positions).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SDStepOut(NamedTuple):
    tokens: jax.Array        # [N+1] emitted tokens, -1 padded beyond n_emitted
    n_emitted: jax.Array     # scalar in [1, N+1]
    n_accepted: jax.Array    # scalar in [0, N]  (accepted draft tokens)
    cur: jax.Array           # [B,1] next cur token
    pos: jax.Array           # new pos
    dcache: Any
    tcache: Any
    draft_tokens: jax.Array  # [N] proposed drafts (for analytics)
    taps: Any                # draft taps, stacked [N, ...] (predictor input)


def make_sd_step(draft_model, target_model, draft_len: int,
                 collect_taps: bool = False):
    """Build a jittable SD step for batch-size-1 decoding (paper §4.2)."""
    N = draft_len

    def sd_step(dparams, tparams, dcache, tcache, cur, pos) -> SDStepOut:
        B = cur.shape[0]

        # ---- drafting stage (autoregressive scan over the draft model) ----
        def draft_body(carry, _):
            tok, cache, p = carry
            logits, cache, taps = draft_model.decode_step(
                dparams, cache, tok, p, collect_taps=collect_taps)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return (nxt, cache, p + 1), (nxt[:, 0], taps)

        (_, dcache2, _), (drafts, taps) = jax.lax.scan(
            draft_body, (cur, dcache, pos), None, length=N)
        drafts = drafts.T                                   # [B, N]

        # ---- verification stage (single parallel target forward) ----
        block = jnp.concatenate([cur, drafts], axis=1)      # [B, N+1]
        tlogits, tcache2, _ = target_model.decode_step(tparams, tcache, block, pos)
        greedy = jnp.argmax(tlogits, axis=-1).astype(jnp.int32)  # [B, N+1] g_0..g_N

        # ---- greedy acceptance (batch row 0; engine is B=1) ----
        d = drafts[0]                                       # [N]
        g = greedy[0]                                       # [N+1]
        match = d == g[:N]
        acc_prefix = jnp.cumprod(match.astype(jnp.int32))
        n_acc = jnp.sum(acc_prefix)                         # in [0, N]
        n_emit = n_acc + 1
        idx = jnp.arange(N + 1)
        emitted = jnp.where(idx < n_acc, jnp.concatenate([d, jnp.zeros((1,), jnp.int32)]),
                            jnp.where(idx == n_acc, g[n_acc], -1))
        cur_next = g[n_acc][None, None].astype(jnp.int32)
        cur_next = jnp.broadcast_to(cur_next, (B, 1))
        return SDStepOut(tokens=emitted, n_emitted=n_emit, n_accepted=n_acc,
                         cur=cur_next, pos=pos + n_emit, dcache=dcache2,
                         tcache=tcache2, draft_tokens=d, taps=taps)

    return sd_step


# ---------------------------------------------------------------------------
# streaming generators — the single implementation each decode policy runs
# on; the legacy one-shot entry points below and core/engine.py's unified
# Engine both drive these.  Each yields one List[int] chunk per committed
# step/verify block (already clipped to the max_new_tokens budget) and, when
# given a ``stats`` dict, updates "iterations"/"drafted"/"accepted" in place
# per iteration so an early generator close still leaves consistent stats.
# ---------------------------------------------------------------------------

def _bump(stats: Optional[dict], iters=0, drafted=0, accepted=0, **extra):
    if stats is None:
        return
    stats["iterations"] = stats.get("iterations", 0) + iters
    stats["drafted"] = stats.get("drafted", 0) + drafted
    stats["accepted"] = stats.get("accepted", 0) + accepted
    for k, v in extra.items():
        stats.setdefault(k, []).append(v)


def make_greedy_step(model):
    """Jitted single-token decode step (cache it per engine, not per call)."""
    return jax.jit(lambda p, c, t, ps: model.decode_step(p, c, t, ps))


def adaptive_next_len(n: int, n_accepted: int, acc_ewma: float,
                      min_len: int, max_len: int, ewma: float
                      ) -> Tuple[int, float]:
    """THE acceptance-EWMA draft-length controller — shared by
    sd_adaptive_stream and the offload engine's decode loop so the
    sd-adaptive axis behaves identically on every offload policy.

    ±1 steps keep the stale-cache overwrite invariant: the next block
    (N_new+1 tokens from pos+n+1) must cover the previous iteration's
    rejected writes (N_prev-n positions); N_new >= N_prev-1 suffices.
    Returns (next_n, next_ewma)."""
    frac = n_accepted / max(n, 1)
    acc_ewma = (1 - ewma) * acc_ewma + ewma * frac
    if acc_ewma > 0.8 and n < max_len:
        n += 1
    elif acc_ewma < 0.4 and n > min_len:
        n -= 1
    return n, acc_ewma


def greedy_stream(model, params, prompt: jax.Array, max_new_tokens: int,
                  max_seq: int, stats: Optional[dict] = None, step=None):
    """Vanilla autoregressive greedy decoding, one token per chunk."""
    if max_new_tokens <= 0:
        return
    logits, cache = model.prefill(params, prompt, max_seq)
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    pos = prompt.shape[1]
    emitted = 1
    yield [int(cur[0, 0])]
    if step is None:
        step = make_greedy_step(model)
    while emitted < max_new_tokens:
        lg, cache, _ = step(params, cache, cur, jnp.int32(pos))
        cur = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos += 1
        emitted += 1
        _bump(stats, iters=1)
        yield [int(cur[0, 0])]


def sd_stream(draft_model, target_model, dparams, tparams, prompt: jax.Array,
              max_new_tokens: int, draft_len: int, max_seq: int,
              stats: Optional[dict] = None, step=None):
    """Fixed-N speculative decoding, one chunk per verify block."""
    assert prompt.shape[0] == 1, "SD engine is batch-1 (paper §4.2)"
    if max_new_tokens <= 0:
        return
    if step is None:
        step = jax.jit(make_sd_step(draft_model, target_model, draft_len))
    tlog, tcache = target_model.prefill(tparams, prompt, max_seq)
    _, dcache = draft_model.prefill(dparams, prompt, max_seq)
    cur = jnp.argmax(tlog, axis=-1).astype(jnp.int32)[:, None]
    pos = prompt.shape[1]
    emitted = 1
    yield [int(cur[0, 0])]
    while emitted < max_new_tokens:
        res = step(dparams, tparams, dcache, tcache, cur, jnp.int32(pos))
        n = int(res.n_emitted)
        toks = [int(t) for t in res.tokens[:n]]
        cur, pos, dcache, tcache = res.cur, int(res.pos), res.dcache, res.tcache
        _bump(stats, iters=1, drafted=draft_len, accepted=int(res.n_accepted))
        chunk = toks[:max_new_tokens - emitted]
        emitted += len(chunk)
        yield chunk


def sd_adaptive_stream(draft_model, target_model, dparams, tparams,
                       prompt: jax.Array, max_new_tokens: int, max_seq: int,
                       min_len: int = 1, max_len: int = 8, ewma: float = 0.5,
                       stats: Optional[dict] = None, step_for=None):
    """Acceptance-adaptive draft length (beyond-paper, see sd_generate_adaptive
    docstring), one chunk per verify block."""
    assert prompt.shape[0] == 1
    if max_new_tokens <= 0:
        return
    if step_for is None:
        steps = {}

        def step_for(n):
            if n not in steps:
                steps[n] = jax.jit(make_sd_step(draft_model, target_model, n))
            return steps[n]

    tlog, tcache = target_model.prefill(tparams, prompt, max_seq)
    _, dcache = draft_model.prefill(dparams, prompt, max_seq)
    cur = jnp.argmax(tlog, axis=-1).astype(jnp.int32)[:, None]
    pos = prompt.shape[1]
    emitted = 1
    yield [int(cur[0, 0])]
    n = min_len
    acc_ewma = 0.5
    while emitted < max_new_tokens:
        res = step_for(n)(dparams, tparams, dcache, tcache, cur, jnp.int32(pos))
        k = int(res.n_emitted)
        toks = [int(t) for t in res.tokens[:k]]
        cur, pos, dcache, tcache = res.cur, int(res.pos), res.dcache, res.tcache
        _bump(stats, iters=1, drafted=n, accepted=int(res.n_accepted),
              draft_lens=n)
        n, acc_ewma = adaptive_next_len(n, int(res.n_accepted), acc_ewma,
                                        min_len, max_len, ewma)
        chunk = toks[:max_new_tokens - emitted]
        emitted += len(chunk)
        yield chunk


# ---------------------------------------------------------------------------
# legacy one-shot entry points (kept as the internal/reference layer —
# public callers go through core/engine.py's Engine)
# ---------------------------------------------------------------------------

def sd_generate(draft_model, target_model, dparams, tparams,
                prompt: jax.Array, max_new_tokens: int, draft_len: int,
                max_seq: int) -> Tuple[jax.Array, Dict[str, float]]:
    """One-shot fixed-N SD: prompt [1, P] -> (tokens [<= max_new_tokens],
    stats).  Thin wrapper over :func:`sd_stream`."""
    c: Dict[str, int] = {}
    out: list = []
    for chunk in sd_stream(draft_model, target_model, dparams, tparams,
                           prompt, max_new_tokens, draft_len, max_seq,
                           stats=c):
        out.extend(chunk)
    iters = c.get("iterations", 0)
    stats = {
        "iterations": iters,
        "acceptance_rate": c.get("accepted", 0) / max(iters * draft_len, 1),
        "tokens_per_iteration": len(out) / max(iters, 1),
    }
    return jnp.array(out, jnp.int32), stats


def sd_generate_adaptive(draft_model, target_model, dparams, tparams,
                         prompt: jax.Array, max_new_tokens: int, max_seq: int,
                         min_len: int = 1, max_len: int = 8,
                         ewma: float = 0.5) -> Tuple[jax.Array, Dict[str, float]]:
    """Beyond-paper: acceptance-adaptive draft length.

    The paper fixes N per run (Fig. 13 sweeps it offline).  This controller
    tracks an EWMA of the per-iteration acceptance fraction and grows/
    shrinks N online: high acceptance -> longer drafts amortize the target's
    weight stream further (see EXPERIMENTS.md §Perf cell 1); low acceptance
    -> shorter drafts stop wasting draft compute + prefetch bandwidth.
    Lossless for any schedule (greedy acceptance is N-oblivious).
    Thin wrapper over :func:`sd_adaptive_stream`.
    """
    c: Dict[str, int] = {}
    out: list = []
    for chunk in sd_adaptive_stream(draft_model, target_model, dparams,
                                    tparams, prompt, max_new_tokens, max_seq,
                                    min_len=min_len, max_len=max_len,
                                    ewma=ewma, stats=c):
        out.extend(chunk)
    iters = c.get("iterations", 0)
    lens = c.get("draft_lens", [])
    return jnp.array(out, jnp.int32), {
        "iterations": iters,
        "acceptance_rate": c.get("accepted", 0) / max(c.get("drafted", 0), 1),
        "tokens_per_iteration": len(out) / max(iters, 1),
        "final_draft_len": lens[-1] if lens else min_len,
        "mean_draft_len": float(np.mean(lens)) if lens else float(min_len),
    }


def greedy_generate(model, params, prompt: jax.Array, max_new_tokens: int,
                    max_seq: int) -> jax.Array:
    """Vanilla autoregressive greedy decoding (the lossless reference).
    Thin wrapper over :func:`greedy_stream`."""
    out: list = []
    for chunk in greedy_stream(model, params, prompt, max_new_tokens, max_seq):
        out.extend(chunk)
    return jnp.array(out, jnp.int32)
