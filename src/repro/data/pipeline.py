"""Deterministic synthetic LM data pipeline with per-host sharding and
background prefetch.

Tokens follow a Zipf distribution with injected n-gram structure (so training
loss actually decreases and MoE gating sees realistic skew).  Every batch is
a pure function of (seed, host, step): restarts and elastic re-sharding
reproduce the exact stream — the property fault-tolerance tests rely on.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    zipf_a: float = 1.3
    ngram_rep: float = 0.3     # probability of copying a recent token (structure)


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.p = p / p.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for (host, step) — deterministic, restart-stable."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.host_id)
        B, S = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self.p)
        # n-gram structure: with prob ngram_rep, copy the token 4 back
        rep = rng.random((B, S + 1)) < cfg.ngram_rep
        for off in (4,):
            toks[:, off:] = np.where(rep[:, off:], toks[:, :-off], toks[:, off:])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class PrefetchIterator:
    """Background-thread prefetch of upcoming batches (depth-bounded)."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.ds = ds
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.ds.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        s, b = self.q.get()
        self.step = s + 1
        return b

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def make_pipeline(cfg: ModelConfig, shape: ShapeConfig, *, num_hosts: int = 1,
                  host_id: int = 0, seed: int = 0,
                  start_step: int = 0) -> PrefetchIterator:
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, num_hosts=num_hosts,
                    host_id=host_id, seed=seed)
    return PrefetchIterator(SyntheticLM(dc), start_step)
