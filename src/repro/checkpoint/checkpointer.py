"""Sharded checkpointing with async save, atomic commit, and elastic restore.

Layout: <dir>/step_<n>/{meta.json, host<k>.npz} — each host writes its
addressable shards (on this single-host container that is the full tree;
the per-host split is the same code path real pods use).  Writes go to a
temp dir renamed into place, so a crash mid-save never corrupts the latest
checkpoint.  ``restore`` device_puts into the CURRENT mesh's shardings —
restoring onto a different mesh (elastic scale-up/down after failures) is
just a different sharding argument.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    """Host snapshot.  bf16 (an ml_dtypes type numpy can't round-trip through
    npz) is widened to f32 — exact, and cast back on restore."""
    import jax.numpy as jnp
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = jax.device_get(leaf)
        if hasattr(arr, "dtype") and arr.dtype == jnp.bfloat16:
            arr = np.asarray(jnp.asarray(arr, jnp.float32))
        flat[key] = np.asarray(arr)
    return flat


def _unflatten_into(treedef_example, flat: Dict[str, np.ndarray]):
    import jax.numpy as jnp
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(treedef_example)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jnp.asarray(arr).astype(leaf.dtype)   # jnp handles bf16
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(treedef_example)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any], blocking: bool = False):
        """Async by default: snapshot to host, write on a background thread."""
        flat = {name: _flatten(tree) for name, tree in state.items()}
        self.wait()

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{self.host_id}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            for name, tree in flat.items():
                np.savez(os.path.join(tmp, f"{name}.host{self.host_id}.npz"), **tree)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "names": list(flat.keys())}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Restore into pytrees shaped like ``like``; optionally device_put
        with per-state shardings (elastic re-shard happens here)."""
        path = os.path.join(self.dir, f"step_{step}")
        out = {}
        for name, tree in like.items():
            with np.load(os.path.join(path, f"{name}.host{self.host_id}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            restored = _unflatten_into(tree, flat)
            if shardings and name in shardings and shardings[name] is not None:
                restored = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), restored, shardings[name])
            out[name] = restored
        return out
