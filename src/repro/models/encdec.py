"""Whisper-style encoder-decoder.  The conv/audio frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
``[B, encoder_seq, d_model]``.  Decoder blocks: self-attn (causal, cached) +
cross-attn over the encoder output (K/V cached at prefill) + FFN.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def _init_enc_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": L.init_rms_norm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ln2": L.init_rms_norm(cfg.d_model, dtype),
            "ffn": L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_activation, dtype)}


def _init_dec_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": L.init_rms_norm(cfg.d_model, dtype),
            "self_attn": L.init_attention(ks[0], cfg, dtype),
            "ln_x": L.init_rms_norm(cfg.d_model, dtype),
            "cross_attn": L.init_attention(ks[1], cfg, dtype),
            "ln2": L.init_rms_norm(cfg.d_model, dtype),
            "ffn": L.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_activation, dtype)}


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    def init(self, key) -> Params:
        cfg, dtype = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.num_layers)
        return {
            "wte": L._dense_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype,
                                 scale=jnp.sqrt(cfg.d_model)),
            "enc": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
            "dec": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
            "ln_enc": L.init_rms_norm(cfg.d_model, dtype),
            "ln_f": L.init_rms_norm(cfg.d_model, dtype),
        }

    # -- encoder -------------------------------------------------------------
    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """frames: [B, S_enc, d] (stub frontend output) -> encoder states."""
        cfg = self.cfg
        B, S, _ = frames.shape
        full = jnp.ones((1, 1, 1, S, S), bool)      # bidirectional

        def body(x, lp):
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            x = x + L.attention_forward(lp["attn"], h, cfg, mask=full)
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.ffn_forward(lp["ffn"], h, cfg.ffn_activation)
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, frames.astype(self.dtype), params["enc"])
        return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)

    # -- decoder -------------------------------------------------------------
    def _dec_block(self, lp, x, enc_kv, cfg, mode, cache, pos):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if mode == "decode":
            a, cache = L.attention_decode(lp["self_attn"], h, cache, pos, cfg)
        else:
            a = L.attention_forward(lp["self_attn"], h, cfg)
        x = x + a
        h = L.rms_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + L.attention_forward(lp["cross_attn"], h, cfg,
                                    kv_override=enc_kv, use_rope=False,
                                    mask=None)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn_forward(lp["ffn"], h, cfg.ffn_activation)
        return x, cache

    def _cross_kv(self, params: Params, enc: jax.Array):
        """Per-decoder-layer cross-attention K/V (computed once)."""
        def one(lp):
            k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"])
            return k, v
        return jax.vmap(one)(params["dec"])        # [L, B, S_enc, H, hd]

    def forward(self, params: Params, tokens: jax.Array, frames: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        enc = self.encode(params, frames)
        kv = self._cross_kv(params, enc)
        x = jnp.take(params["wte"], tokens, axis=0)

        def body(x, xs):
            lp, k, v = xs
            x, _ = self._dec_block(lp, x, (k, v), cfg, "train", None, None)
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, (params["dec"], kv[0], kv[1]))
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["wte"])
        return logits, jnp.zeros((), jnp.float32)

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        one = L.init_kv_cache(cfg, batch, max_seq, self.dtype)
        self_kv = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one)
        cross = {
            "k": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), self.dtype),
            "v": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), self.dtype),
        }
        return {"self": self_kv, "cross": cross}

    def prefill(self, params: Params, tokens: jax.Array, max_seq: int,
                frames: jax.Array) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        enc = self.encode(params, frames)
        kv = self._cross_kv(params, enc)
        cache = self.init_cache(tokens.shape[0], max_seq)
        cache["cross"] = {"k": kv[0], "v": kv[1]}
        x = jnp.take(params["wte"], tokens, axis=0)

        def body(carry, xs):
            x = carry
            lp, k, v, blockc = xs
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            from repro.models.transformer import _attn_prefill_cache
            blockc = _attn_prefill_cache({"attn": lp["self_attn"]}, h, cfg,
                                         blockc, None)
            x, _ = self._dec_block(lp, x, (k, v), cfg, "train", None, None)
            return x, blockc

        x, self_kv = jax.lax.scan(body, x,
                                  (params["dec"], kv[0], kv[1], cache["self"]))
        cache["self"] = self_kv
        x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["wte"])[:, 0]
        return logits, cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos, collect_taps: bool = False):
        cfg = self.cfg
        x = jnp.take(params["wte"], tokens, axis=0)

        def body(carry, xs):
            x = carry
            lp, ck, cv, blockc = xs
            x, nc = self._dec_block(lp, x, (ck, cv), cfg, "decode", blockc, pos)
            return x, nc

        x, self_kv = jax.lax.scan(
            body, x, (params["dec"], cache["cross"]["k"], cache["cross"]["v"],
                      cache["self"]))
        cache = dict(cache)
        cache["self"] = self_kv
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["wte"])
        return logits, cache, {}
