"""Path-based sharding rules: param/cache/batch PartitionSpecs per mesh.

Rules are keyed on parameter names (stable across the model zoo) with
divisibility guards — an axis is sharded only when the dim divides the mesh
axis size, so every (arch × mesh) combination lowers cleanly.

Conventions:
* ``model``          tensor-parallel axis: heads, d_ff, vocab, experts (EP
                     when E divides), ssm channels.
* ``fsdp`` =(pod,data) weight sharding for training (ZeRO-3-style; XLA
                     all-gathers weights per layer inside the scan, which its
                     latency-hiding scheduler overlaps with compute) and for
                     serving models too big to replicate per data shard.
* activations        batch over (pod, data); sequence over model between
                     blocks when seq-sharding is on (sequence parallelism).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# stacks whose leaves carry leading layer dims
_STACK1 = ("layers", "dense_layers", "tail", "enc", "dec")
_STACK2 = ("mamba_groups",)


def mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(dim: int, mesh: Mesh, axes) -> Optional[Any]:
    """axes if dim divides their product, else None."""
    if axes is None:
        return None
    size = mesh_axis_size(mesh, axes)
    return axes if (size > 1 and dim % size == 0) else None


def _leaf_spec(path_names: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, mesh: Mesh, fsdp) -> P:
    """PartitionSpec for one parameter leaf (without stack dims)."""
    name = path_names[-1]
    M = "model"

    def ax(dim_idx, axes):
        return _fit(shape[dim_idx], mesh, axes)

    if name == "wte":                       # [V, d]
        v = ax(0, M)
        return P(v, ax(1, fsdp))
    if name == "head":                      # [d, V]
        return P(ax(0, fsdp), ax(1, M))
    if len(shape) == 1:                     # norms / biases / A_log / D
        return P(None)
    if name == "wq":                        # [d, H, hd]
        return P(ax(0, fsdp), ax(1, M), None)
    if name in ("wk", "wv"):                # [d, Hkv, hd]
        return P(ax(0, fsdp), ax(1, M), None)
    if name == "wo":                        # [H, hd, d]
        return P(ax(0, M), None, ax(2, fsdp))
    if name in ("wdkv", "wkr"):             # [d, r]
        return P(ax(0, fsdp), None)
    if name in ("wuk", "wuv"):              # [r, H, hd]
        return P(ax(0, fsdp), ax(1, M), None)
    if name == "gate":                      # [d, E] — small, replicated
        return P(None, None)
    if name in ("wg", "wu") and len(shape) == 3:   # experts [E, d, f]
        e = ax(0, M)
        if e is not None:
            return P(e, ax(1, fsdp), None)         # EP
        return P(None, ax(1, fsdp), ax(2, M))      # TP over f
    if name == "wd" and len(shape) == 3:           # experts [E, f, d]
        e = ax(0, M)
        if e is not None:
            return P(e, None, ax(2, fsdp))
        return P(None, ax(1, M), ax(2, fsdp))
    if name in ("wg", "wu"):                # dense ffn [d, f]
        return P(ax(0, fsdp), ax(1, M))
    if name == "wd":                        # dense ffn [f, d]
        return P(ax(0, M), ax(1, fsdp))
    if name == "in_proj":                   # [d, dproj]
        return P(ax(0, fsdp), ax(1, M))
    if name == "conv_w":                    # [W, ch]
        return P(None, ax(1, M))
    if name == "out_proj":                  # [d_in, d]
        return P(ax(0, M), ax(1, fsdp))
    return P(*([None] * len(shape)))


def _stack_depth(path_names: Tuple[str, ...]) -> int:
    d = 0
    for n in path_names:
        if n in _STACK1:
            d = 1
        if n in _STACK2:
            d = 2
    return d


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return tuple(out)


def param_pspecs(cfg: ModelConfig, param_shapes, mesh: Mesh,
                 mode: str = "train", weight_gather: Optional[bool] = None):
    """Tree of PartitionSpec matching ``param_shapes`` (a ShapeDtypeStruct
    tree from eval_shape).

    mode="train": weights FSDP-sharded over (pod, data) + TP over model.
    mode="serve": TP only, unless the per-data-shard replica would exceed
    ~10 GB (or weight_gather=True), in which case FSDP sharding stays on and
    XLA gathers weights per layer on the fly.
    """
    if mode == "serve":
        if weight_gather is None:
            total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                        for l in jax.tree.leaves(param_shapes))
            per_model_shard = total / max(mesh_axis_size(mesh, "model"), 1)
            weight_gather = per_model_shard > 10e9
        fsdp = data_axes(mesh) if weight_gather else None
    else:
        fsdp = fsdp_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        depth = _stack_depth(names)
        base = _leaf_spec(names, tuple(leaf.shape[depth:]), cfg, mesh, fsdp)
        return P(*([None] * depth + list(base)))

    return jax.tree_util.tree_map_with_path(spec, param_shapes)


def cache_pspecs(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """KV/SSM cache shardings for serving: batch over (pod,data); heads over
    model when they divide, else the sequence dim (distributed flash-decode:
    XLA all-reduces the softmax partials)."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        depth = _stack_depth(names)
        shape = tuple(leaf.shape[depth:])
        if name == "pos_map":
            return P(*([None] * leaf.ndim))
        if name in ("k", "v"):               # [B, S, Hkv, hd]
            b = _fit(shape[0], mesh, dp)
            h = _fit(shape[2], mesh, "model")
            s = None if h is not None else _fit(shape[1], mesh, "model")
            return P(*([None] * depth), b, s, h, None)
        if name in ("c_kv", "k_rope"):       # [B, S, r]
            b = _fit(shape[0], mesh, dp)
            s = _fit(shape[1], mesh, "model")
            return P(*([None] * depth), b, s, None)
        if name == "ssm":                    # [B, H, P, N]
            b = _fit(shape[0], mesh, dp)
            h = _fit(shape[1], mesh, "model")
            return P(*([None] * depth), b, h, None, None)
        if name == "conv":                   # [B, W-1, ch]
            b = _fit(shape[0], mesh, dp)
            c = _fit(shape[2], mesh, "model")
            return P(*([None] * depth), b, None, c)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def batch_pspec(mesh: Mesh) -> P:
    return P(data_axes(mesh), None)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
