"""Sequence-parallel Mamba2 (SSD) — the collective-bound hillclimb for
mamba2-780m prefill_32k (EXPERIMENTS.md §Perf).

Baseline TP shards ``d_inner`` over the model axis, paying two activation
all-reduces per layer (the dominant roofline term for this small-d_model
arch).  Here instead:

* weights are REPLICATED over the model axis (mamba2-780m is 1.6 GB — fits);
* the SEQUENCE is sharded over the model axis; every pointwise op
  (projections, norms, gating) is shard-local;
* the SSD recurrence crosses shards through two tiny collectives per layer:
    - a width-(W-1) halo exchange (collective-permute) for the causal conv;
    - an all-gather of per-shard (final_state [B,H,P,N], total_decay [B,H])
      followed by a local prefix combine — the cross-shard state is then
      folded in closed form:  y_t += C_t · (state_in ⊙ exp(dA_cum_t)).

Collective bytes per layer drop from O(tokens · d_model) to
O(shards · B · H · P · N) — about 400x for the prefill_32k cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.configs.base import ModelConfig
from repro.kernels.ref import ssd_ref
from repro.models import layers as L
from repro.models import mamba as M

Params = Dict[str, Any]


def _mamba_block_local(p: Params, x: jax.Array, cfg: ModelConfig,
                       axis: str) -> jax.Array:
    """One mamba block on a sequence shard (runs inside shard_map).

    x: [B, S_loc, d].  Cross-shard pieces: conv halo + SSD state prefix.
    """
    Bsz, S, _ = x.shape
    d_in, H, N, conv_ch = M._dims(cfg)
    n_shards = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
                else jax.lax.psum(1, axis))      # jax 0.4.x spelling
    idx = jax.lax.axis_index(axis)

    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dp->bsp", h, p["mamba"]["in_proj"])
    z, xs, Bm, Cm, dt = M._split(zxbcdt, cfg)
    xBC_pre = jnp.concatenate([xs, Bm, Cm], -1)

    # --- causal conv with halo from the left neighbour ---
    W = cfg.ssm_conv_width
    halo = xBC_pre[:, -(W - 1):, :]
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    halo_in = jax.lax.ppermute(halo, axis, perm)
    halo_in = jnp.where(idx == 0, jnp.zeros_like(halo_in), halo_in)
    padded = jnp.concatenate([halo_in, xBC_pre], axis=1)
    conv = sum(padded[:, i:i + S, :] * p["mamba"]["conv_w"][i]
               for i in range(W))
    xBC = jax.nn.silu(conv + p["mamba"]["conv_b"])

    xs2, Bm2, Cm2 = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xh = xs2.reshape(Bsz, S, H, cfg.ssm_head_dim)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["mamba"]["dt_bias"])
    A = -jnp.exp(p["mamba"]["A_log"])

    # --- local SSD with zero initial state ---
    chunk = min(cfg.ssm_chunk, S)
    assert S % chunk == 0, "shard length must be a chunk multiple"
    y_loc, state_loc = ssd_ref(xh, dtf, A, Bm2, Cm2, chunk)

    # --- cross-shard state prefix ---
    dA_cum = jnp.cumsum(dtf * A, axis=1)                 # [B, S, H]
    total_decay = jnp.exp(dA_cum[:, -1, :])              # [B, H]
    states = jax.lax.all_gather(state_loc, axis)         # [n, B, H, P, N]
    decays = jax.lax.all_gather(total_decay, axis)       # [n, B, H]
    prefix = jnp.zeros_like(state_loc)
    prefixes = [prefix]
    for j in range(n_shards - 1):
        prefix = prefix * decays[j][:, :, None, None] + states[j]
        prefixes.append(prefix)
    state_in = jnp.stack(prefixes)[idx]                  # [B, H, P, N]
    # fold the incoming state: y_t += C_t . (state_in * exp(dA_cum_t))
    y_corr = jnp.einsum("bsn,bhpn->bshp", Cm2.astype(jnp.float32),
                        state_in) * jnp.exp(dA_cum)[..., None]
    y = y_loc.astype(jnp.float32) + y_corr
    y = y + xh.astype(jnp.float32) * p["mamba"]["D"][:, None]
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["mamba"]["norm"], cfg.norm_eps)
    out = jnp.einsum("bsp,pd->bsd", y, p["mamba"]["out_proj"])
    return x + out


def seq_parallel_forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
                         mesh: Mesh, axis: str = "model") -> jax.Array:
    """Full mamba2 LM forward with the sequence sharded over ``axis``.

    Weights replicated over ``axis``; batch sharded over (pod, data) by the
    caller's in_shardings.  Returns last-position logits [B, V].
    """
    assert cfg.family == "ssm"

    def body(params, tokens):
        x = jnp.take(params["wte"], tokens, axis=0)

        def layer(x, lp):
            return _mamba_block_local(lp, x, cfg, axis), None

        x, _ = jax.lax.scan(layer, x, params["layers"])
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x

    # shard_map over the model axis only; batch/data sharding is handled by
    # the outer pjit (the specs below say how ONE (data-)shard's slice is
    # split across the model axis).
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = dp if (dp and tokens.shape[0] % dp_size == 0) else None
    try:
        fn = _shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(bspec, axis)),
            out_specs=P(bspec, axis, None),
            check_vma=False,
        )
    except TypeError:  # jax 0.4.x spells the kwarg check_rep
        fn = _shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(bspec, axis)),
            out_specs=P(bspec, axis, None),
            check_rep=False,
        )
    x = fn(params, tokens)
    logits = jnp.einsum("bd,dv->bv", x[:, -1, :], params["head"]) \
        if not cfg.tie_embeddings else \
        jnp.einsum("bd,vd->bv", x[:, -1, :], params["wte"])
    return logits
