"""Mamba2 (SSD) block: in_proj -> causal conv -> SSD -> gated RMSNorm -> out_proj.

Single B/C group (ngroups=1).  Full-sequence path uses the chunked SSD scan
(kernels/ref.ssd_ref oracle; Pallas kernel swaps in on TPU via kernels/ops).
Decode is the O(1) recurrent update with a rolling conv state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, rms_norm
from repro.kernels.ref import ssd_ref, ssd_decode_ref

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state_dim
    conv_ch = d_in + 2 * N           # conv over (x, B, C)
    return d_in, H, N, conv_ch


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    d_in, H, N, conv_ch = _dims(cfg)
    proj_out = 2 * d_in + 2 * N + H  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32) *
                 (np.log(0.1) - np.log(0.001)) + np.log(0.001))
    return {
        "in_proj": _dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), dtype, scale=1.0),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": _dense_init(ks[3], (d_in, d), dtype),
    }


def _split(z_x_b_c_dt: jax.Array, cfg: ModelConfig):
    d_in, H, N, _ = _dims(cfg)
    z, xs, B, C, dt = jnp.split(
        z_x_b_c_dt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xs, B, C, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xBC: [B,S,ch]; w: [W,ch]."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def mamba_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  init_state: Optional[jax.Array] = None) -> jax.Array:
    """x: [B,S,d] -> [B,S,d] (full-sequence SSD)."""
    Bsz, S, _ = x.shape
    d_in, H, N, _ = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    z, xs, Bm, Cm, dt = _split(zxbcdt, cfg)
    xBC = _causal_conv(jnp.concatenate([xs, Bm, Cm], -1), p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xh = xs.reshape(Bsz, S, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:  # pad to a chunk multiple (masked by dt=0 -> no state change)
        padn = chunk - S % chunk
        xh = jnp.pad(xh, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padn), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, padn), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, padn), (0, 0)))
    y, _ = ssd_ref(xh, dt, A, Bm, Cm, chunk, init_state)
    y = y[:, :S]
    y = y + xs.reshape(Bsz, S, H, cfg.ssm_head_dim) * p["D"][:, None]
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bsp,pd->bsd", y, p["out_proj"])


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    d_in, H, N, conv_ch = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def mamba_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                 cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent step.  x: [B,1,d]."""
    Bsz = x.shape[0]
    d_in, H, N, conv_ch = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])[:, 0]
    z, xs, Bm, Cm, dt = _split(zxbcdt, cfg)
    xBC_new = jnp.concatenate([xs, Bm, Cm], -1)                 # [B, ch]
    window = jnp.concatenate([cache["conv"], xBC_new[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xh = xs.reshape(Bsz, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_decode_ref(cache["ssm"], xh, dt, A, Bm, Cm)
    y = y + xh * p["D"][:, None]
    y = y.reshape(Bsz, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bp,pd->bd", y, p["out_proj"])[:, None, :]
    return out, {"ssm": new_state, "conv": window[:, 1:, :]}
