"""Core layers: RMSNorm, RoPE, GQA/MQA/MLA attention (+KV caches, sliding
window, absorbed MLA decode), FFN variants.

All functions are pure; parameters are plain dicts of jnp arrays.  Naming is
stable because sharding rules (models/sharding.py) key off parameter paths.
Compute dtype follows the input; reductions (softmax, norms) run in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def init_rms_norm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2] (f32)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S] (int32)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                  # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * inv        # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masked multi-head attention (einsum form, SPMD-friendly)
# ---------------------------------------------------------------------------

def mha(q: jax.Array, k: jax.Array, v: jax.Array,
        mask: Optional[jax.Array], softcap: Optional[float] = None) -> jax.Array:
    """q: [B,Sq,H,D]  k: [B,Skv,Hkv,D]  v: [B,Skv,Hkv,Dv]  -> [B,Sq,H,Dv].

    GQA via head-group reshape; mask broadcastable to [B, 1|Hkv, 1|rep, Sq, Skv]
    (True = attend).  Softmax in f32.
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, D)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(D)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def causal_mask(sq: int, skv: int, window: Optional[int] = None) -> jax.Array:
    """[1,1,1,Sq,Skv] causal (optionally sliding-window) mask.

    Positions are aligned to the *end*: query i sits at absolute position
    skv - sq + i.
    """
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None, None]


# ---------------------------------------------------------------------------
# GQA attention block (dense / mixtral / zamba2 shared / whisper)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, H, hd), dtype),
        "wk": _dense_init(ks[1], (d, Hkv, hd), dtype),
        "wv": _dense_init(ks[2], (d, Hkv, hd), dtype),
        "wo": _dense_init(ks[3], (H, hd, d), dtype),
    }


def attention_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                      positions: Optional[jax.Array] = None,
                      mask: Optional[jax.Array] = None,
                      kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                      use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill).  x: [B,S,D]."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is not None:                      # cross-attention
        k, v = kv_override
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if use_rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    if (cfg.attn_impl == "kernel" and kv_override is None and mask is None
            and cfg.attn_logit_softcap is None):
        # Pallas flash attention (Mosaic on TPU, interpret elsewhere);
        # handles causal + sliding-window + GQA with blocked online softmax
        from repro.kernels.flash_attention import flash_attention
        import jax as _jax
        out = flash_attention(q, k, v, causal=True,
                              window=cfg.sliding_window,
                              block_q=min(128, S), block_k=min(128, S),
                              interpret=_jax.default_backend() != "tpu")
    else:
        if mask is None and kv_override is None:
            mask = causal_mask(S, k.shape[1], cfg.sliding_window)
        out = mha(q, k, v, mask, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# Rolling SWA caches get margin slots beyond the window so a speculative
# verification block (up to this many tokens) never clobbers slots that are
# still inside the window for the block's earlier queries.
SWA_RING_MARGIN = 16


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    """KV cache.  Rolling buffer when sliding window is on (mixtral
    long-context).  ``pos_map[s]`` records the absolute position held by slot
    ``s`` (-1 = empty); masks are derived from it, which makes multi-token
    verification blocks and rolling-buffer wraparound uniformly correct.
    """
    seq = (min(max_seq, cfg.sliding_window + SWA_RING_MARGIN)
           if cfg.sliding_window else max_seq)
    shp = (batch, seq, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "pos_map": jnp.full((seq,), -1, jnp.int32)}


def attention_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                     pos: jax.Array, cfg: ModelConfig,
                     use_rope: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode a block of Sq >= 1 tokens at absolute positions pos..pos+Sq-1
    (Sq > 1 = speculative-verification block).  x: [B,Sq,D]; pos: scalar.

    RoPE is applied at write time with the token's absolute position; for
    sliding-window configs the cache is a rolling buffer (slot = pos % W) and
    validity comes from the stored per-slot absolute positions.
    """
    B, Sq, _ = x.shape
    S = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    qpos = pos + jnp.arange(Sq, dtype=jnp.int32)
    if use_rope:
        pp = jnp.broadcast_to(qpos[None, :], (B, Sq))
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)
    slots = jnp.mod(qpos, S) if cfg.sliding_window else qpos
    ck = cache["k"].at[:, slots].set(k)
    cv = cache["v"].at[:, slots].set(v)
    pos_map = cache["pos_map"].at[slots].set(qpos)
    # mask: [1,1,1,Sq,S] — slot valid for query i iff it holds a position
    # <= qpos[i] (and within the window for SWA).
    valid = (pos_map[None, :] <= qpos[:, None]) & (pos_map[None, :] >= 0)
    if cfg.sliding_window:
        valid &= pos_map[None, :] > qpos[:, None] - cfg.sliding_window
    mask = valid[None, None, None]
    out = mha(q, ck, cv, mask, cfg.attn_logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": ck, "v": cv, "pos_map": pos_map}


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2): compressed KV cache + absorbed decode
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vd, r = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, H, nope + rope_d), dtype),
        "wdkv": _dense_init(ks[1], (d, r), dtype),
        "wkr": _dense_init(ks[2], (d, rope_d), dtype),
        "wuk": _dense_init(ks[3], (r, H, nope), dtype),
        "wuv": _dense_init(ks[4], (r, H, vd), dtype),
        "wo": _dense_init(ks[5], (H, vd, d), dtype),
    }


def mla_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence MLA (train / prefill): expand the latent, run GQA-style."""
    B, S, _ = x.shape
    nope = cfg.head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])                 # latent
    k_rope = apply_rope(jnp.einsum("bsd,dk->bsk", x, p["wkr"])[:, :, None, :],
                        positions, cfg.rope_theta)                 # [B,S,1,rd]
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"])
    H = cfg.num_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.rope_head_dim))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    out = mha(qf, k, v, causal_mask(S, S))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
        "pos_map": jnp.full((max_seq,), -1, jnp.int32),
    }


def mla_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
               pos: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-matmul MLA decode: attention runs in the `kv_lora` latent
    space, so per-step cost is O(S·r) instead of O(S·H·head_dim) and the cache
    stays compressed.  Scaling uses the expanded head dim (nope+rope) to match
    the full-sequence path exactly.  Handles Sq >= 1 (verification blocks).
    """
    B, Sq, _ = x.shape
    nope, rd, r = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    S = cache["c_kv"].shape[1]
    qpos = pos + jnp.arange(Sq, dtype=jnp.int32)
    pp = jnp.broadcast_to(qpos[None, :], (B, Sq))
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])                    # [B,Sq,H,nope+rd]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pp, cfg.rope_theta)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])         # absorb W_uk
    c_new = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    kr_new = apply_rope(jnp.einsum("bsd,dk->bsk", x, p["wkr"])[:, :, None, :],
                        pp, cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, pos, 0))
    pos_map = jax.lax.dynamic_update_slice(cache["pos_map"], qpos, (pos,))
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv) +
              jnp.einsum("bshk,btk->bhst", q_rope, k_rope)).astype(jnp.float32)
    scores = scores / np.sqrt(nope + rd)
    valid = (pos_map[None, :] <= qpos[:, None]) & (pos_map[None, :] >= 0)
    scores = jnp.where(valid[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv)            # latent context
    ctx = jnp.einsum("bshr,rhk->bshk", ctx_lat, p["wuv"])          # absorb W_uv
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope, "pos_map": pos_map}


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

def init_ffn(key, d: int, f: int, activation: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {"wg": _dense_init(ks[0], (d, f), dtype),
                "wu": _dense_init(ks[1], (d, f), dtype),
                "wd": _dense_init(ks[2], (f, d), dtype)}
    return {"wu": _dense_init(ks[0], (d, f), dtype),
            "wd": _dense_init(ks[1], (f, d), dtype)}


def ffn_forward(p: Params, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["wu"])
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x, p["wu"])))
    elif activation == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wu"]))
    else:
        raise ValueError(activation)
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])


def ffn_params_per_layer(cfg: ModelConfig, f: Optional[int] = None) -> int:
    f = f if f is not None else cfg.d_ff
    mats = 3 if cfg.ffn_activation == "swiglu" else 2
    return mats * cfg.d_model * f
