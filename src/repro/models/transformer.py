"""Decoder-only LM assembly: dense / moe / ssm / hybrid families.

Layers with identical structure are stacked and driven by ``lax.scan`` (one
compiled body regardless of depth — the MaxText pattern), with optional
``jax.checkpoint`` remat per layer.  Three modes share one code path:

* ``train``    full sequence, no cache, returns (logits, aux_loss)
* ``prefill``  full sequence, fills caches
* ``decode``   one token, consumes + updates caches; optionally returns the
               per-layer gate-input taps the SP-MoE predictor feeds into the
               target model's gating networks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"ln1": L.init_rms_norm(cfg.d_model, dtype),
                "mamba": M.init_mamba(ks[0], cfg, dtype)}
    p: Params = {"ln1": L.init_rms_norm(cfg.d_model, dtype),
                 "ln2": L.init_rms_norm(cfg.d_model, dtype)}
    if cfg.use_mla:
        p["attn"] = L.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if kind == "moe":
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_activation, dtype)
    return p


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int,
                     dtype) -> Params:
    if kind == "mamba":
        return M.init_mamba_cache(cfg, batch, dtype)
    if cfg.use_mla:
        return L.init_mla_cache(cfg, batch, max_seq, dtype)
    return L.init_kv_cache(cfg, batch, max_seq, dtype)


def block_apply(p: Params, x: jax.Array, kind: str, cfg: ModelConfig,
                mode: str, cache: Optional[Params], pos,
                positions: Optional[jax.Array]
                ) -> Tuple[jax.Array, Optional[Params], jax.Array, jax.Array]:
    """-> (x_out, new_cache, aux_loss, gate_input_tap)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, cache = M.mamba_decode(p["mamba"], h, cache, cfg)
        else:
            y = M.mamba_forward(p["mamba"], h, cfg)
            if mode == "prefill":
                cache = _mamba_prefill_cache(p, h, cfg)
        x = x + y
        return x, cache, aux, x
    # attention half
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mode == "decode":
        if cfg.use_mla:
            a, cache = L.mla_decode(p["attn"], h, cache, pos, cfg)
        else:
            a, cache = L.attention_decode(p["attn"], h, cache, pos, cfg)
    else:
        if cfg.use_mla:
            a = L.mla_forward(p["attn"], h, cfg, positions)
        else:
            a = L.attention_forward(p["attn"], h, cfg, positions)
        if mode == "prefill":
            cache = _attn_prefill_cache(p, h, cfg, cache, positions)
    x = x + a
    # ffn half
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        # serving paths (decode AND prefill) must be drop-free: capacity
        # drops would corrupt the KV-cache-vs-decode equivalence that
        # speculative-decoding losslessness rests on.  Training keeps
        # capacity-based routing (standard, differentiable-drop regime).
        y, aux = MOE.moe_forward(p["moe"], h2, cfg, decode=(mode != "train"))
    else:
        y = L.ffn_forward(p["ffn"], h2, cfg.ffn_activation)
    x = x + y
    return x, cache, aux, h2       # tap = gate input (SP-MoE predictor input)


def _attn_prefill_cache(p, h, cfg: ModelConfig, cache, positions):
    """Recompute k/v (cheap vs attention) and write them into the cache."""
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.use_mla:
        c_kv = jnp.einsum("bsd,dr->bsr", h, p["attn"]["wdkv"])
        k_rope = L.apply_rope(jnp.einsum("bsd,dk->bsk", h, p["attn"]["wkr"])[:, :, None, :],
                              positions, cfg.rope_theta)[:, :, 0, :]
        cache = dict(cache)
        cache["c_kv"] = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, 0, 0))
        cache["k_rope"] = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, 0, 0))
        cache["pos_map"] = jax.lax.dynamic_update_slice(
            cache["pos_map"], jnp.arange(S, dtype=jnp.int32), (0,))
        return cache
    k = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"]), positions, cfg.rope_theta)
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
    W = cache["k"].shape[1]            # ring size (window + margin for SWA)
    if cfg.sliding_window and S > W:   # rolling buffer keeps the last W tokens
        k, v = k[:, -W:], v[:, -W:]
        # rolled so that slot (pos % W) layout matches decode-side indexing
        shift = (S % W)
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        pos_map = (S - W) + jnp.mod(jnp.arange(W) - S, W).astype(jnp.int32)
        S = W
    else:
        pos_map = jnp.where(jnp.arange(cache["pos_map"].shape[0]) < S,
                            jnp.arange(cache["pos_map"].shape[0]), -1).astype(jnp.int32)
    return {"k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
            "pos_map": pos_map}


def _mamba_prefill_cache(p, h, cfg: ModelConfig):
    """Run the pieces needed to produce (ssm_state, conv window) after h."""
    Bsz, S, _ = h.shape
    d_in, H, N, conv_ch = M._dims(cfg)
    zxbcdt = jnp.einsum("bsd,dp->bsp", h, p["mamba"]["in_proj"])
    z, xs, Bm, Cm, dt = M._split(zxbcdt, cfg)
    xBC_pre = jnp.concatenate([xs, Bm, Cm], -1)
    xBC = M._causal_conv(xBC_pre, p["mamba"]["conv_w"], p["mamba"]["conv_b"])
    xs2, Bm2, Cm2 = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xh = xs2.reshape(Bsz, S, H, cfg.ssm_head_dim)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["mamba"]["dt_bias"])
    A = -jnp.exp(p["mamba"]["A_log"])
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:
        padn = chunk - S % chunk
        xh = jnp.pad(xh, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, padn), (0, 0)))
        Bm2 = jnp.pad(Bm2, ((0, 0), (0, padn), (0, 0)))
        Cm2 = jnp.pad(Cm2, ((0, 0), (0, padn), (0, 0)))
    from repro.kernels.ref import ssd_ref
    _, final_state = ssd_ref(xh, dtf, A, Bm2, Cm2, chunk)
    W = cfg.ssm_conv_width
    convwin = jnp.pad(xBC_pre, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :] \
        if S >= 1 else jnp.zeros((Bsz, W - 1, conv_ch), h.dtype)
    return {"ssm": final_state.astype(jnp.float32), "conv": convwin}


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

class DecoderLM:
    """Families: dense, moe, ssm, hybrid, vlm (vlm adds patch inputs)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # -- structure ----------------------------------------------------------
    def _stacks(self):
        """Layer layout: list of (name, kind, count, shared)."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            groups = cfg.num_layers // cfg.attn_every
            tail = cfg.num_layers % cfg.attn_every
            out = [("mamba_groups", "mamba", (groups, cfg.attn_every - 1), False),
                   ("shared_attn", "dense", 1, True)]
            if tail:
                out.append(("tail", "mamba", tail, False))
            return out
        if cfg.family == "ssm":
            return [("layers", "mamba", cfg.num_layers, False)]
        if cfg.is_moe:
            out = []
            if cfg.first_dense_layers:
                out.append(("dense_layers", "dense", cfg.first_dense_layers, False))
            out.append(("layers", "moe", cfg.num_moe_layers, False))
            return out
        return [("layers", "dense", cfg.num_layers, False)]

    def init(self, key) -> Params:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: Params = {
            "wte": L._dense_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype, scale=np.sqrt(cfg.d_model)),
            "ln_f": L.init_rms_norm(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = L._dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dtype)
        ki = 2
        for name, kind, count, shared in self._stacks():
            if shared:
                params[name] = init_block(keys[ki], kind, cfg, dtype)
            elif isinstance(count, tuple):
                g, per = count
                ks = jax.random.split(keys[ki], g * per).reshape(g, per, -1)
                params[name] = jax.vmap(jax.vmap(
                    lambda k: init_block(k, kind, cfg, dtype)))(ks)
            else:
                ks = jax.random.split(keys[ki], count)
                params[name] = jax.vmap(
                    lambda k: init_block(k, kind, cfg, dtype))(ks)
            ki += 1
        return params

    def init_cache(self, batch: int, max_seq: int) -> Params:
        cfg, dtype = self.cfg, self.dtype
        cache: Params = {}
        for name, kind, count, shared in self._stacks():
            one = lambda: init_block_cache(kind, cfg, batch, max_seq, dtype)
            if shared:
                g = cfg.num_layers // cfg.attn_every
                cache[name] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (g,) + x.shape).copy(), one())
            elif isinstance(count, tuple):
                g, per = count
                cache[name] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (g, per) + x.shape).copy(), one())
            else:
                cache[name] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (count,) + x.shape).copy(), one())
        return cache

    # -- scanned stack application -------------------------------------------
    def _apply_stack(self, name, kind, shared, lp, x, mode, cache, pos,
                     positions, collect_taps):
        cfg = self.cfg

        def body(carry, xs):
            x, aux = carry
            blockp, blockc = xs
            xo, nc, a, tap = block_apply(blockp, x, kind, cfg, mode, blockc,
                                         pos, positions)
            tap_out = tap if collect_taps else jnp.zeros((), x.dtype)
            return (xo, aux + a), (nc, tap_out)

        body_fn = _maybe_remat(body, cfg, mode)

        if shared:
            # shared weights applied at each site; caches stacked per site
            def sbody(carry, xs):
                x, aux = carry
                blockc = xs
                xo, nc, a, tap = block_apply(lp, x, kind, cfg, mode, blockc,
                                             pos, positions)
                return (xo, aux + a), (nc, tap if collect_taps else jnp.zeros((), x.dtype))
            sfn = _maybe_remat(sbody, cfg, mode)
            (x, aux), (ncache, taps) = jax.lax.scan(sfn, (x, jnp.zeros((), jnp.float32)), cache)
            return x, aux, ncache, taps
        (x, aux), (ncache, taps) = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), (lp, cache))
        return x, aux, ncache, taps

    def _run(self, params: Params, x: jax.Array, mode: str,
             cache: Optional[Params], pos, positions,
             collect_taps: bool = False):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_cache: Params = {}
        taps: Dict[str, jax.Array] = {}
        stacks = self._stacks()
        if cfg.family == "hybrid":
            # interleave: scan over groups of (per-group mamba scan + shared attn)
            gp = params["mamba_groups"]
            gcache = (cache or {}).get("mamba_groups")
            acache = (cache or {}).get("shared_attn")
            groups = cfg.num_layers // cfg.attn_every
            if gcache is None:
                gcache = jnp.zeros((groups, cfg.attn_every - 1), jnp.float32)
                acache = _broadcast_none(groups)

            def group_body(carry, xs):
                x, aux = carry
                gparams, gc, ac = xs

                def mbody(c, mxs):
                    xx, a = c
                    bp, bc = mxs
                    xo, nc, al, _ = block_apply(bp, xx, "mamba", cfg, mode, bc, pos, positions)
                    return (xo, a + al), nc
                (x, aux), ngc = jax.lax.scan(mbody, (x, aux), (gparams, gc))
                x, nac, al, _ = block_apply(params["shared_attn"], x, "dense",
                                            cfg, mode, ac, pos, positions)
                return (x, aux + al), (ngc, nac)

            gfn = _maybe_remat(group_body, cfg, mode)
            (x, aux_total), (ngc, nac) = jax.lax.scan(
                gfn, (x, aux_total), (gp, gcache, acache))
            new_cache["mamba_groups"], new_cache["shared_attn"] = ngc, nac
            if "tail" in params:
                tc = (cache or {}).get("tail", _none_like(params["tail"], None))
                x, aux, ntc, _ = self._apply_stack("tail", "mamba", False,
                                                   params["tail"], x, mode, tc,
                                                   pos, positions, False)
                aux_total += aux
                new_cache["tail"] = ntc
        else:
            for name, kind, count, shared in stacks:
                scache = (cache or {}).get(name)
                if scache is None:
                    n = count if not shared else cfg.num_layers // cfg.attn_every
                    scache = _broadcast_none(n)
                x, aux, ncache, tp = self._apply_stack(
                    name, kind, shared, params[name], x, mode, scache, pos,
                    positions, collect_taps)
                aux_total += aux
                new_cache[name] = ncache
                if collect_taps:
                    taps[name] = tp
        return x, aux_total, new_cache, taps

    # -- public API -----------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                patch_embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
        """train-mode full forward.  tokens: [B,S] -> (logits [B,S,V], aux)."""
        cfg = self.cfg
        x = jnp.take(params["wte"], tokens, axis=0)
        if cfg.family == "vlm":
            assert patch_embeds is not None
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        x, aux, _, _ = self._run(params, x, "train", None, None, None)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._head(params, x)
        if cfg.family == "vlm":
            logits = logits[:, patch_embeds.shape[1]:]
        return logits, aux

    def prefill(self, params: Params, tokens: jax.Array, max_seq: int,
                patch_embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
        """Fill caches with a prompt; return (last-position logits, cache)."""
        cfg = self.cfg
        x = jnp.take(params["wte"], tokens, axis=0)
        if cfg.family == "vlm" and patch_embeds is not None:
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        cache = self.init_cache(tokens.shape[0], max_seq)
        x, _, cache, _ = self._run(params, x, "prefill", cache, None, None)
        x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        return self._head(params, x)[:, 0], cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos, collect_taps: bool = False):
        """tokens: [B,Sq] at positions pos..pos+Sq-1 (Sq>1 = speculative
        verification block) -> (logits [B,Sq,V], new_cache, taps)."""
        cfg = self.cfg
        x = jnp.take(params["wte"], tokens, axis=0)
        x, _, cache, taps = self._run(params, x, "decode", cache, pos, None,
                                      collect_taps)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._head(params, x)
        return logits, cache, taps

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["wte"])
        return jnp.einsum("bsd,dv->bsv", x, params["head"])


def _maybe_remat(fn, cfg, mode):
    """Per-layer remat: full recompute, or selective (matmul outputs saved,
    elementwise recomputed — ~0 extra FLOPs, moderate extra memory)."""
    if not (cfg.remat and mode == "train"):
        return fn
    if cfg.remat_policy == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _none_like(tree, leading):
    """Cache placeholder for cacheless modes: scan needs a pytree of xs with a
    matching leading dim; use zeros of shape [n] (ignored by train mode)."""
    first = jax.tree.leaves(tree)[0]
    n = first.shape[0]
    return _broadcast_none(n)


def _broadcast_none(n):
    return jnp.zeros((n,), jnp.float32)
