"""Analytical cost model: params, FLOPs, bytes, collective bytes per
(arch × shape × mesh).

``cost_analysis()`` on this JAX build reports per-device numbers and visits
scan bodies once (no trip-count multiplication — verified empirically, see
DESIGN.md §6), so the roofline terms come from this exact closed-form model;
tests/test_costmodel.py cross-validates single-layer FLOPs against XLA's
``cost_analysis`` on a per-layer lowering.

All counts are GLOBAL (whole step across the cluster); roofline divides by
chip count.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

BYTES = {"bfloat16": 2, "float32": 4}


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> int:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.use_mla:
        r, rd, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.v_head_dim
        return (d * H * (hd + rd)       # wq
                + d * r + d * rd        # wdkv, wkr
                + r * H * hd + r * H * vd
                + H * vd * d)           # wo
    return d * H * hd + 2 * d * Hkv * hd + H * hd * d


def _ffn_params(cfg: ModelConfig, f: Optional[int] = None) -> int:
    f = cfg.d_ff if f is None else f
    mats = 3 if cfg.ffn_activation == "swiglu" else 2
    return mats * cfg.d_model * f


def _moe_params(cfg: ModelConfig) -> Tuple[int, int]:
    """(per-layer total expert params, per-layer active expert params)."""
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    total = cfg.num_experts * per_expert + cfg.d_model * cfg.num_experts
    shared = cfg.num_shared_experts * per_expert
    active = cfg.num_experts_per_tok * per_expert + shared
    return total + shared, active


def _mamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = cfg.d_inner
    N = cfg.ssm_state_dim
    H = d_in // cfg.ssm_head_dim
    proj = 2 * d_in + 2 * N + H
    conv_ch = d_in + 2 * N
    return (d * proj + cfg.ssm_conv_width * conv_ch + conv_ch
            + 3 * H + d_in + d_in * d)


def expert_param_bytes(cfg: ModelConfig) -> int:
    """One routed expert's bytes (the unit of SP-MoE offloading I/O)."""
    return 3 * cfg.d_model * cfg.moe_d_ff * BYTES[cfg.dtype]


def non_expert_bytes(cfg: ModelConfig) -> int:
    """Resident bytes when all routed experts are offloaded."""
    total, _ = count_params(cfg)
    if cfg.is_moe:
        routed = cfg.num_moe_layers * cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff
        return (total - routed) * BYTES[cfg.dtype]
    return total * BYTES[cfg.dtype]


def count_params(cfg: ModelConfig) -> Tuple[int, int]:
    """(total_params, active_params_per_token)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d
    head = 0 if cfg.tie_embeddings else d * cfg.vocab_size
    total = emb + head + d
    active = emb + head + d
    kinds = cfg.layer_kinds()
    shared_attn_counted = False
    for kind in kinds:
        if kind == "mamba":
            p = _mamba_params(cfg) + d
            total += p
            active += p
        elif kind == "moe":
            attn = _attn_params(cfg) + 2 * d
            tot_moe, act_moe = _moe_params(cfg)
            total += attn + tot_moe
            active += attn + act_moe
        else:
            p = _attn_params(cfg) + 2 * d
            f = _ffn_params(cfg)
            if cfg.family == "hybrid":
                if not shared_attn_counted:
                    total += p + f
                    shared_attn_counted = True
                active += p + f
            else:
                total += p + f
                active += p + f
    if cfg.family == "encdec":
        enc = cfg.encoder_layers * (_attn_params(cfg) + _ffn_params(cfg) + 2 * d)
        dec_cross = cfg.num_layers * (_attn_params(cfg) + d)
        total += enc + dec_cross
        active += enc + dec_cross
    return int(total), int(active)


# ---------------------------------------------------------------------------
# FLOPs (training fwd+bwd = 3x fwd matmul flops; fwd = 2 * active params
# per token + attention quadratic term)
# ---------------------------------------------------------------------------

def _attn_flops_per_layer(cfg: ModelConfig, seq_q: int, seq_kv: int,
                          batch: int) -> int:
    """Score+context matmul FLOPs for one attention layer (full block)."""
    if cfg.family == "ssm":
        return 0
    H, hd = cfg.num_heads, cfg.head_dim
    if cfg.use_mla:
        # absorbed decode dims differ but the full-seq path dominates costs
        hd = cfg.head_dim + cfg.rope_head_dim
    win = cfg.sliding_window
    eff_kv = min(seq_kv, win) if win else seq_kv
    if seq_q == seq_kv:   # causal full pass: ~half the square
        pair = (seq_q * eff_kv // 2 if not win or seq_q > win
                else seq_q * seq_q // 2)
    else:
        pair = seq_q * eff_kv
    return 2 * 2 * batch * H * pair * hd


def _n_attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds() if k != "mamba")


def _ssd_flops_per_layer(cfg: ModelConfig, seq: int, batch: int) -> int:
    d_in = cfg.d_inner
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state_dim
    Q = cfg.ssm_chunk
    P_ = cfg.ssm_head_dim
    nc = max(seq // Q, 1)
    # CB [Q,Q] + (CB.L)@X + state build/apply per chunk per head
    per_chunk = 2 * Q * Q * N + 2 * Q * Q * P_ + 2 * 2 * Q * N * P_
    return batch * H * nc * per_chunk


def step_flops(cfg: ModelConfig, shape: ShapeConfig,
               remat: Optional[bool] = None,
               capacity_factor: Optional[float] = None) -> Dict[str, float]:
    """Global FLOPs for one step of this (arch, shape) cell.

    ``useful`` follows the PaLM MFU convention: parameter matmuls + attention
    dot products at fwd=1x / train=3x.  ``total`` adds the real overheads:
    full per-layer remat recompute (train: +1 fwd pass) and MoE
    capacity-factor padding waste (train routing path).
    """
    B = shape.global_batch
    remat = cfg.remat if remat is None else remat
    cf = cfg.capacity_factor if capacity_factor is None else capacity_factor
    _, active = count_params(cfg)
    if shape.kind == "decode":
        tokens = B  # one new token per sequence
        matmul = 2 * active * tokens
        attn = sum(_attn_flops_per_layer(cfg, 1, shape.seq_len, B)
                   for k in cfg.layer_kinds() if k != "mamba")
        ssd = sum(2 * 2 * (cfg.d_inner // cfg.ssm_head_dim) * cfg.ssm_head_dim
                  * cfg.ssm_state_dim * B
                  for k in cfg.layer_kinds() if k == "mamba")
        if cfg.family == "encdec":
            attn += cfg.num_layers * _attn_flops_per_layer(
                cfg, 1, cfg.encoder_seq, B)
        total = matmul + attn + ssd
        return {"total": float(total), "useful": float(total),
                "matmul": float(matmul), "attn": float(attn + ssd),
                "tokens": float(tokens)}
    tokens = B * shape.seq_len
    matmul = 2 * active * tokens
    # MoE capacity-factor waste (train routing pads each expert to capacity)
    moe_waste = 0.0
    if cfg.is_moe and shape.kind == "train" and cf > 1.0:
        per_tok_expert = (cfg.num_experts_per_tok * 3 * cfg.d_model *
                          cfg.moe_d_ff * cfg.num_moe_layers)
        moe_waste = 2 * per_tok_expert * tokens * (cf - 1.0)
    attn = sum(_attn_flops_per_layer(cfg, shape.seq_len, shape.seq_len, B)
               for k in cfg.layer_kinds() if k != "mamba")
    ssd = sum(_ssd_flops_per_layer(cfg, shape.seq_len, B)
              for k in cfg.layer_kinds() if k == "mamba")
    if cfg.family == "encdec":
        attn += (cfg.encoder_layers *
                 2 * _attn_flops_per_layer(cfg, cfg.encoder_seq, cfg.encoder_seq, B)
                 + cfg.num_layers * _attn_flops_per_layer(
                     cfg, shape.seq_len, cfg.encoder_seq, B))
    fwd = matmul + attn + ssd
    if shape.kind == "train":
        useful = 3.0 * fwd
        # full per-layer remat recomputes the forward during backward
        total = (4.0 if remat else 3.0) * (fwd + moe_waste)
    else:
        useful = fwd
        total = fwd + moe_waste
    return {"total": float(total), "useful": float(useful),
            "matmul": float(matmul), "attn": float(attn + ssd),
            "tokens": float(tokens)}


# ---------------------------------------------------------------------------
# memory traffic & footprint
# ---------------------------------------------------------------------------

def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> int:
    b = BYTES[cfg.dtype]
    total = 0
    for kind in cfg.layer_kinds():
        if kind == "mamba":
            d_in = cfg.d_inner
            H = d_in // cfg.ssm_head_dim
            total += batch * (H * cfg.ssm_head_dim * cfg.ssm_state_dim * 4
                              + (cfg.ssm_conv_width - 1) * (d_in + 2 * cfg.ssm_state_dim) * b)
        elif cfg.use_mla:
            total += batch * seq * (cfg.kv_lora_rank + cfg.rope_head_dim) * b
        else:
            eff = min(seq, cfg.sliding_window + 16) if cfg.sliding_window else seq
            total += 2 * batch * eff * cfg.num_kv_heads * cfg.head_dim * b
    if cfg.family == "encdec":
        total += 2 * cfg.num_layers * batch * cfg.encoder_seq * \
            cfg.num_kv_heads * cfg.head_dim * b
    return int(total)


def _unique_experts_touched(cfg: ModelConfig, n_tokens: int) -> float:
    """E[#unique experts activated by n_tokens] (uniform proxy)."""
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    return E * (1.0 - (1.0 - 1.0 / E) ** (n_tokens * k))


def weights_read_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Weight bytes one replica must stream through HBM for one step.
    For MoE decode only the activated experts' weights are touched."""
    pb = BYTES[cfg.dtype]
    total_p, active_p = count_params(cfg)
    if not (cfg.is_moe and shape.kind == "decode"):
        return float(total_p * pb)
    uniq = _unique_experts_touched(cfg, shape.global_batch)
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed_all = cfg.num_moe_layers * cfg.num_experts * per_expert
    routed_touched = cfg.num_moe_layers * uniq * per_expert
    return float((total_p - routed_all + routed_touched) * pb)


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig,
                   mesh_shape: Optional[Dict[str, int]] = None,
                   weight_gather: bool = False) -> float:
    """Global HBM traffic for one step, SHARDING-AWARE.

    Weights replicated over the data axis (serve default for small models)
    are read once per replica per step — the dominant decode cost.  With
    weight-gathered (ZeRO-style) serving the weights are read once globally
    (plus one extra pass for the gathered copy's write+read).
    """
    pb = BYTES[cfg.dtype]
    total_p, active_p = count_params(cfg)
    B = shape.global_batch
    dp = 1
    if mesh_shape:
        dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    if shape.kind == "decode":
        w = weights_read_bytes(cfg, shape)
        if weight_gather:
            w = w * 2.0          # read shards once + write/read gathered copy
        else:
            w = w * dp           # every data replica streams its own copy
        return float(w + kv_cache_bytes(cfg, B, shape.seq_len))
    tokens = B * shape.seq_len
    act = tokens * cfg.d_model * pb * cfg.num_layers  # remat-resident stream
    if shape.kind == "train":
        # fwd read + bwd read + grad write + opt update read/write (f32 m,v)
        return float(total_p * (pb * 3 + 4 * 4) + 2 * act)
    return float(total_p * pb + act)


# ---------------------------------------------------------------------------
# collective bytes (per step, summed over all devices' sends)
# ---------------------------------------------------------------------------

def collective_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: Dict[str, int],
                     mode: str, weight_gather: bool = False) -> Dict[str, float]:
    """Closed-form collective-traffic model for the rule set in sharding.py.

    Returns global bytes moved per step per collective family.  Per-chip ICI
    time = total / (chips × link_bw) (the roofline's collective term).
    """
    pb = BYTES[cfg.dtype]
    model = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = model * dp
    total_p, _ = count_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (1 if shape.kind == "decode" else S)
    d = cfg.d_model
    out: Dict[str, float] = {"all_gather": 0.0, "reduce_scatter": 0.0,
                             "all_reduce": 0.0, "all_to_all": 0.0}
    # --- weight gathers (FSDP): each chip receives the other (dp-1)/dp of
    # its model-shard's weights, fwd (+bwd for train)
    fsdp_on = mode == "train" or weight_gather
    if fsdp_on and dp > 1:
        passes = 2 if shape.kind == "train" else 1
        out["all_gather"] += passes * chips * (total_p * pb / model) * (dp - 1) / dp
    # --- gradient reduce-scatter + opt-state all-gather equivalents (train)
    if shape.kind == "train" and dp > 1:
        out["reduce_scatter"] += chips * (total_p * pb / model) * (dp - 1) / dp
    # --- TP activation collectives: per attention/ffn block, the partial-sum
    # outputs are all-reduced over the model axis (2 per layer fwd)
    if model > 1:
        act_bytes = tokens * d * pb
        nlayers = cfg.num_layers + (cfg.encoder_layers or 0)
        passes = 4 if shape.kind == "train" else 2
        out["all_reduce"] += passes * nlayers * act_bytes * 2 * (model - 1) / model
        # EP all-to-all (deepseek-style E % model == 0): token dispatch+return
        if cfg.is_moe and cfg.num_experts % model == 0:
            k = cfg.num_experts_per_tok
            out["all_to_all"] += 2 * cfg.num_moe_layers * tokens * k * d * pb \
                * (model - 1) / model
    return out


def roofline_terms(cfg: ModelConfig, shape: ShapeConfig,
                   mesh_shape: Dict[str, int], mode: str,
                   weight_gather: bool = False,
                   remat: Optional[bool] = None,
                   capacity_factor: Optional[float] = None,
                   grad_compress: bool = False, verify_block: int = 1,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9,
                   ici_bw: float = 50e9) -> Dict[str, float]:
    """The three §Roofline terms (seconds) + bookkeeping."""
    chips = int(np.prod(list(mesh_shape.values())))
    fl = step_flops(cfg, shape, remat=remat, capacity_factor=capacity_factor)
    if verify_block > 1 and shape.kind == "decode":
        # SD verification: one step processes verify_block tokens, so the
        # per-step weight read amortizes over the block (flops/tokens scale,
        # hbm stays per-step)
        fl = {k: v * verify_block for k, v in fl.items()}
    hbm = step_hbm_bytes(cfg, shape, mesh_shape, weight_gather)
    coll = collective_bytes(cfg, shape, mesh_shape, mode, weight_gather)
    if grad_compress and shape.kind == "train":
        from repro.optim.grad_compress import compressed_bytes_fraction
        # int8+EF compression applies to the DP gradient reduce-scatter
        coll["reduce_scatter"] *= compressed_bytes_fraction() * 2  # vs bf16
    coll_total = sum(coll.values())
    t_comp = fl["total"] / (chips * peak_flops)
    t_mem = hbm / (chips * hbm_bw)
    t_coll = coll_total / (chips * ici_bw)
    total_p, active_p = count_params(cfg)
    model_flops = fl["useful"]
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction = ideal time / achieved bound, where the ideal is the
    # hardware floor for this op: useful FLOPs at peak, but never below the
    # mandatory HBM traffic (weights once, plus the KV/SSM cache for decode).
    # = MFU when compute-bound; = bandwidth utilization when memory-bound.
    hbm_floor = weights_read_bytes(cfg, shape)
    if shape.kind == "decode":
        hbm_floor += kv_cache_bytes(cfg, shape.global_batch, shape.seq_len)
    ideal = max(model_flops / (chips * peak_flops),
                hbm_floor / (chips * hbm_bw))
    return {
        "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
        "dominant": dominant,
        "flops": fl["total"], "hbm_bytes": hbm, "collective_bytes": coll_total,
        "collectives": coll,
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(fl["total"], 1.0),
        "roofline_fraction": ideal / max(bound, 1e-30),
        "params_total": total_p, "params_active": active_p,
        "tokens": fl["tokens"],
    }
