"""MoE FFN block: top-k gating + static-shaped capacity routing.

Two routing formulations, both fully static (jit/pjit-safe):

* ``route_grouped`` (train / prefill): routing and capacity are resolved
  *per batch row*, so token gathers are ``take_along_axis`` on the sequence
  dim — sharding-local under batch-sharded activations (no token all-gather).
  This is the GShard grouping trick with gather/scatter instead of the dense
  one-hot dispatch einsum, so dispatch memory is O(E·C·d), not O(S·E·C).
* ``route_global`` (decode): tokens are few (= batch), so routing is done on
  the flat token set; compute is a batched per-expert einsum over
  ``[E, C, d]`` with C = ceil(cf·T·k/E) — FLOP overhead is just the capacity
  factor, never E/k.

Expert weights are ``[E, d, f]``; sharding rules put E on the model axis when
it divides (EP) else f (TP) — see models/sharding.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, ffn_forward, init_ffn

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "gate": _dense_init(ks[0], (d, E), jnp.float32),
        "wg": _dense_init(ks[1], (E, d, f), dtype),
        "wu": _dense_init(ks[2], (E, d, f), dtype),
        "wd": _dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_ffn(ks[4], d, cfg.num_shared_experts * f, "swiglu", dtype)
    return p


def gate_topk(gate_w: jax.Array, x: jax.Array, k: int
              ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x: [..., d] -> (weights [..., k], ids [..., k], probs [..., E], aux).

    Mixtral-style: softmax over all experts, take top-k, renormalize.
    aux = switch load-balancing loss (E · mean(frac_routed · mean_prob)).
    """
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), gate_w)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    E = gate_w.shape[-1]
    flat_ids = ids.reshape(-1, k)
    counts = jnp.sum(jax.nn.one_hot(flat_ids, E, dtype=jnp.float32), axis=(0, 1))
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_prob = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    return weights.astype(x.dtype), ids, probs, aux


def _expert_ffn(p: Params, xg: jax.Array, activation: str) -> jax.Array:
    """xg: [..., E, C, d] -> [..., E, C, d] via per-expert FFN (batched einsum)."""
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xg, p["wg"]))
        h = h * jnp.einsum("...ecd,edf->...ecf", xg, p["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", xg, p["wu"]))
    return jnp.einsum("...ecf,efd->...ecd", h, p["wd"])


def _dispatch_indices(ids: jax.Array, E: int, C: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ids: [T, k] -> (idx [E, C] token index per slot, valid [E, C],
    slot_of [T, k] slot each (token,choice) landed in, C if dropped)."""
    T, k = ids.shape
    flat = ids.reshape(-1)                                  # [T*k]
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    # rank within expert group
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank = jnp.arange(T * k) - starts[sorted_e]
    tok = order // k
    idx = jnp.zeros((E, C), jnp.int32).at[sorted_e, rank].set(
        tok.astype(jnp.int32), mode="drop")
    valid = jnp.zeros((E, C), jnp.bool_).at[sorted_e, rank].set(True, mode="drop")
    slot_unsorted = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.where(rank < C, rank, C).astype(jnp.int32))
    return idx, valid, slot_unsorted.reshape(T, k)


def moe_grouped(p: Params, x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """Train / prefill path.  x: [B, S, d] -> (y, aux_loss).

    Routing + capacity per batch row (vmapped dispatch), gathers stay local
    to the batch shard.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = int(np.ceil(cfg.capacity_factor * S * k / E))
    C = max(8, min(S, -(-C // 8) * 8))  # round up to 8, cap at S
    weights, ids, _, aux = gate_topk(p["gate"], x, k)

    idx, valid, slot = jax.vmap(lambda i: _dispatch_indices(i, E, C))(ids)
    #   idx/valid: [B, E, C]; slot: [B, S, k]
    xg = jnp.take_along_axis(x[:, None, :, :],                      # [B,1,S,d]
                             idx[..., None], axis=2)                # [B,E,C,d]
    yg = _expert_ffn(p, xg, cfg.ffn_activation)
    yg = yg * valid[..., None]
    # combine: for each (token, choice), read back from (expert, slot)
    ygp = jnp.pad(yg, ((0, 0), (0, 0), (0, 1), (0, 0)))             # slot C = dropped
    y = _combine(ygp, ids, slot, weights)
    if cfg.num_shared_experts:
        y = y + ffn_forward(p["shared"], x, "swiglu")
    return y.astype(x.dtype), aux


def _combine(ygp: jax.Array, ids: jax.Array, slot: jax.Array,
             weights: jax.Array) -> jax.Array:
    """ygp: [B, E, C+1, d]; ids/slot/weights: [B, S, k] -> y [B, S, d]."""
    B, E, Cp1, d = ygp.shape
    S, k = ids.shape[1], ids.shape[2]
    flat = ygp.reshape(B, E * Cp1, d)
    gidx = ids * Cp1 + slot                                         # [B, S, k]
    per_choice = jnp.take_along_axis(
        flat[:, None, :, :], gidx.reshape(B, 1, S * k)[..., None], axis=2
    ).reshape(B, S, k, d)
    return jnp.sum(per_choice * weights[..., None], axis=2)


def moe_global(p: Params, x: jax.Array, cfg: ModelConfig
               ) -> Tuple[jax.Array, jax.Array]:
    """Decode path: drop-free sorted routing + ``lax.ragged_dot`` grouped
    GEMMs.  x: [B, S, d] with tiny B·S (decode / verification blocks).

    FLOPs are exactly T·k·(3·d·f) — no capacity padding, no drops (drops
    would break speculative-decoding losslessness)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xf = x.reshape(T, d)
    weights, ids, _, aux = gate_topk(p["gate"], xf, k)
    flat = ids.reshape(-1)                                  # [T*k]
    order = jnp.argsort(flat, stable=True)
    xs = xf[order // k]                                     # [T*k, d] sorted by expert
    group_sizes = jnp.bincount(flat, length=E).astype(jnp.int32)
    if cfg.ffn_activation == "swiglu":
        h = jax.nn.silu(jax.lax.ragged_dot(xs, p["wg"], group_sizes))
        h = h * jax.lax.ragged_dot(xs, p["wu"], group_sizes)
    else:
        h = jax.nn.gelu(jax.lax.ragged_dot(xs, p["wu"], group_sizes))
    ys = jax.lax.ragged_dot(h, p["wd"], group_sizes)        # [T*k, d]
    y = jnp.zeros((T, d), ys.dtype).at[order // k].add(
        ys * weights.reshape(-1)[order][:, None])
    y = y.reshape(B, S, d)
    if cfg.num_shared_experts:
        y = y + ffn_forward(p["shared"], x, "swiglu")
    return y.astype(x.dtype), aux


def moe_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                decode: bool = False) -> Tuple[jax.Array, jax.Array]:
    if decode or x.shape[0] * x.shape[1] <= 4096 and x.shape[1] <= 8:
        return moe_global(p, x, cfg)
    return moe_grouped(p, x, cfg)


def moe_ref(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Oracle: dense per-token loop over selected experts (no capacity drop).
    Used by tests to validate the routed paths."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    weights, ids, _, _ = gate_topk(p["gate"], x, k)
    xf = x.reshape(-1, d)
    wf = weights.reshape(-1, k)
    idf = ids.reshape(-1, k)
    out = jnp.zeros_like(xf)
    for e in range(E):
        if cfg.ffn_activation == "swiglu":
            h = jax.nn.silu(xf @ p["wg"][e]) * (xf @ p["wu"][e])
        else:
            h = jax.nn.gelu(xf @ p["wu"][e])
        ye = h @ p["wd"][e]
        wsel = jnp.sum(jnp.where(idf == e, wf, 0.0), axis=1)
        out = out + ye * wsel[:, None]
    y = out.reshape(B, S, d)
    if cfg.num_shared_experts:
        y = y + ffn_forward(p["shared"], x, "swiglu")
    return y.astype(x.dtype)
