"""Loss + train_step builder: remat-aware, microbatched (gradient
accumulation via lax.scan), optimizer-fused, pjit-ready.

The returned step has signature ``step(params, opt_state, batch) ->
(params, opt_state, metrics)`` and is pure, so the launcher wraps it in
``jax.jit`` with in/out shardings from models/sharding.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.optim.grad_compress import compress_with_error_feedback


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean CE over all positions, f32, with z-loss regularizer."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    return jnp.mean(ce + z_loss * jnp.square(lse))


def make_loss_fn(model, cfg: ModelConfig):
    def loss_fn(params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        kwargs = {}
        if cfg.family == "vlm":
            kwargs["patch_embeds"] = batch["patch_embeds"]
            logits, aux = model.forward(params, batch["tokens"], **kwargs)
        elif cfg.family == "encdec":
            logits, aux = model.forward(params, batch["tokens"], batch["frames"])
        else:
            logits, aux = model.forward(params, batch["tokens"])
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_loss_coef * aux
        return loss, {"ce": ce, "aux_loss": aux}
    return loss_fn


def make_train_step(model, cfg: ModelConfig, run: RunConfig, optimizer,
                    grad_compress: bool = False):
    """Builds the jittable train step.

    run.microbatch > 0 splits the global batch into microbatches scanned
    sequentially with f32 gradient accumulation (the activation-memory knob
    for the big archs); grad_compress applies int8 error-feedback compression
    to the local gradient contribution before the (XLA-inserted) reduction.
    """
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if run.microbatch and run.microbatch > 1:
            n = run.microbatch

            def split(x):
                b = x.shape[0]
                assert b % n == 0, f"batch {b} not divisible by microbatch {n}"
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, aux), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), aux

            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), auxs = jax.lax.scan(body, (gzero, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / n).astype(jnp.bfloat16), gsum)
            return lsum / n, jax.tree.map(lambda a: a[-1], auxs), grads
        (loss, aux), grads = grad_fn(params, batch)
        return loss, aux, grads

    def train_step(params, opt_state, batch, ef_state=None):
        loss, aux, grads = compute_grads(params, batch)
        if grad_compress:
            grads, ef_state = compress_with_error_feedback(grads, ef_state)
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **aux, **om}
        if grad_compress:
            return params, opt_state, ef_state, metrics
        return params, opt_state, metrics

    return train_step
