"""Unified model API: build_model(cfg) + input_specs(cfg, shape).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of a
given (arch × shape) cell — the dry-run lowers against these without any
device allocation.  Modality frontends are stubs: whisper gets precomputed
frame embeddings, llava gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import DecoderLM
from repro.models.encdec import EncDecLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for train/prefill forward passes."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt),
        }
    elif cfg.family == "vlm":
        # total positions = patches + text; text seq shrinks so the cell's
        # seq_len is the end-to-end sequence length.
        text = max(1, S - cfg.num_patches)
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, text), i32),
            "patch_embeds": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), dt),
        }
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(specs["tokens"].shape, i32)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for one serve_step: new token + KV cache of seq_len + position."""
    B = shape.global_batch
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
