"""Pallas-TPU API compatibility shims.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` in 0.5.x;
the kernels import the name from here so they run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
