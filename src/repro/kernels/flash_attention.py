"""Flash attention (prefill) Pallas TPU kernel.

Blocked online-softmax attention with causal + sliding-window masking and
GQA head folding.  Tiling is MXU/VMEM-oriented: q blocks × kv blocks, f32
accumulators in VMEM scratch, one (head, q-block) owns its accumulator across
the sequential kv-block grid axis.

Oracle: kernels/ref.attention_ref.  Validated in interpret mode
(tests/test_kernels.py); on a real TPU the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, sq: int, skv: int, causal: bool,
                  window: Optional[int], scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # [bq, D]
    k = k_ref[0]                                   # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # absolute positions (queries are right-aligned to the kv sequence)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (skv - sq)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D] -> [B, Sq, H, D].

    Heads fold into the grid's leading (parallel) axis; GQA maps q-head h to
    kv-head h // (H // Hkv) in the k/v index maps.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "pad sequences to block multiples"
    # layout: [B*H, S, D] so a grid step owns one (head, q-block)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, sq=Sq, skv=Skv, causal=causal,
        window=window, scale=1.0 / np.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, Skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, rep=rep, Hkv=Hkv:
                         ((h // rep) % Hkv + (h // (rep * Hkv)) * Hkv, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, rep=rep, Hkv=Hkv:
                         ((h // rep) % Hkv + (h // (rep * Hkv)) * Hkv, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
