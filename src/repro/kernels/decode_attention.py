"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Grid: (batch × kv-head, kv blocks).  The q block is the [rep, D] group of
query heads sharing one kv head (GQA), so the MXU sees a [rep, D] x [D, bk]
matmul per step.  Online softmax across kv blocks; valid-length masking from
a per-batch length vector (SMEM).

Oracle: kernels/ref.decode_attention_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, bk: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # [rep, D]
    k = k_ref[0]                                   # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    length = len_ref[0]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; k/v: [B, S, Hkv, D]; lengths: [B] -> [B, H, D]."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bk = min(block_k, S)
    assert S % bk == 0, "pad the KV cache to a block multiple"
    qf = q.reshape(B * Hkv, rep, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    lens = jnp.repeat(lengths.astype(jnp.int32), Hkv)       # [B*Hkv]

    kernel = functools.partial(_decode_kernel, bk=bk, scale=1.0 / np.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, S // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda h, j: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rep, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, D), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, rep, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, D), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(B, H, D)
