"""Slot-indexed grouped MoE FFN over ExpertCache slot buffers.

The SP-MoE offload runtime keeps a fixed pool of expert-weight *slots* in
device memory (``core/cache.py``); routing produces, per (token, choice), a
**slot id** (via the device-side page table ``[L, E] -> slot | -1``) and a
combine weight.  This kernel computes

    y[t] = sum_c  w[t, c] * FFN_{slots[t, c]}(x[t])        (slots[t,c] >= 0)

entirely on device: tokens are capacity-gathered by slot into ``[S, C, d]``
and pushed through the same blocked gate/up/down Pallas stages as
``moe_gemm.py`` (the slot axis is the leading parallel grid dim), then
combined back with the masked weights.  Choices with ``slot < 0`` (cache
misses, or entries masked out of a compute wave) contribute exactly zero —
cached-first and miss-wave compute share this one fused path, differing only
in which slots are masked.

Verification blocks are tiny (N+1 tokens × k choices), so the capacity per
slot is the worst case ``T·k`` rounded up to the block size — no drops, which
speculative-decoding losslessness requires.  A block can route to at most
``T·k`` *distinct* slots, so when the pool is larger than that the slot axis
is **occupancy-compacted** before the GEMM: the ≤ ``min(S, T·k)`` slots that
actually received a choice are renumbered densely, only their weight rows are
gathered, and the grid covers ``M = min(S, T·k)`` slots instead of ``S`` —
FLOPs and weight traffic are O(M·C·d·f), independent of the pool size.
(Previously the grid covered all S slots at capacity C, burning O(S·C·d·f)
on empty slots — ROADMAP open item, closed.)  Each row's blocked
accumulation is unchanged by the renumbering, so compaction is numerically
transparent.

Oracle: kernels/ref.cache_moe_ref (ragged grouping, same compaction idea).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.kernels.moe_gemm import _down_kernel, _gate_up_kernel, moe_gemm


def _capacity(n_choices: int, block_c: int) -> int:
    """Smallest valid per-slot capacity: >= n_choices (zero drops), rounded so
    the blocked kernel's ``C % bc == 0`` constraint holds."""
    c = max(8, -(-n_choices // 8) * 8)
    if c > block_c:
        c = -(-c // block_c) * block_c
    return c


def compact_occupied_slots(slot_ids: jax.Array, wu: jax.Array, wd: jax.Array,
                           wg: Optional[jax.Array], num_compact: int
                           ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                      Optional[jax.Array]]:
    """Renumber the occupied slots densely into ``[0, num_compact)`` and
    gather just their weight rows.

    slot_ids: [T, k] int (-1 = skip) over a pool of ``S = wu.shape[0]``
    slots.  A block of T·k choices touches at most ``min(S, T·k)`` distinct
    slots, so ``num_compact`` that large is always drop-free.  Returns
    (compact_ids [T, k] in [0, num_compact) ∪ {-1}, wu_c, wd_c, wg_c with a
    leading axis of ``num_compact``).  Unoccupied compact rows keep slot 0's
    weights — harmless, no choice maps to them.
    """
    S = wu.shape[0]
    flat = slot_ids.reshape(-1)
    valid = flat >= 0
    # occupancy via add (a set-scatter would race -1-clipped misses against
    # real hits on slot 0 with differing values)
    counts = jnp.zeros((S,), jnp.int32).at[
        jnp.where(valid, flat, 0)].add(valid.astype(jnp.int32))
    occ = counts > 0
    rank = jnp.cumsum(occ.astype(jnp.int32)) - 1          # dense renumbering
    inv = jnp.where(occ, rank, -1)                        # slot -> compact
    comp2slot = jnp.zeros((num_compact,), jnp.int32).at[
        jnp.where(occ, rank, num_compact)].set(
        jnp.arange(S, dtype=jnp.int32), mode="drop")
    comp_ids = jnp.where(slot_ids >= 0,
                         inv[jnp.clip(slot_ids, 0, S - 1)], -1)
    take = lambda w: None if w is None else jnp.take(w, comp2slot, axis=0)
    return comp_ids, take(wu), take(wd), take(wg)


def dispatch_to_slots(slot_ids: jax.Array, num_slots: int, capacity: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """slot_ids: [T, k] int (-1 = skip) ->
    (idx [S, C] token index per capacity slot, valid [S, C],
    pos [T, k] capacity position each choice landed in; C for skipped).

    Same sorted-rank construction as models/moe._dispatch_indices, but over
    cache slots instead of experts and with a skip lane for negative ids.
    """
    T, k = slot_ids.shape
    flat = slot_ids.reshape(-1)
    sane = jnp.where(flat >= 0, flat, num_slots)          # skips -> overflow row
    order = jnp.argsort(sane, stable=True)
    sorted_s = sane[order]
    starts = jnp.searchsorted(sorted_s, jnp.arange(num_slots))
    rank = jnp.arange(T * k) - starts[sorted_s]
    tok = (order // k).astype(jnp.int32)
    idx = jnp.zeros((num_slots, capacity), jnp.int32).at[
        sorted_s, rank].set(tok, mode="drop")
    valid = jnp.zeros((num_slots, capacity), jnp.bool_).at[
        sorted_s, rank].set(True, mode="drop")
    in_range = (rank < capacity) & (sorted_s < num_slots)
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.where(in_range, rank, capacity).astype(jnp.int32))
    return idx, valid, pos.reshape(T, k)


# --------------------------------------------------------------------------
# gelu stage-1 (single up-projection) — the swiglu stage lives in moe_gemm.py
# --------------------------------------------------------------------------

def _up_gelu_kernel(x_ref, wu_ref, h_ref, acc_ref):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], wu_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(3) - 1)
    def _fin():
        h_ref[0] = jax.nn.gelu(acc_ref[...]).astype(h_ref.dtype)


def _gelu_grouped(xg: jax.Array, wu: jax.Array, wd: jax.Array,
                  valid: jax.Array, *, block_c: int, block_f: int,
                  block_d: int, interpret: bool) -> jax.Array:
    S, C, d = xg.shape
    f = wu.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, f), min(block_d, d)
    assert C % bc == 0 and f % bf == 0 and d % bd == 0
    h = pl.pallas_call(
        _up_gelu_kernel,
        grid=(S, C // bc, f // bf, d // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, kk: (e, i, kk)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, kk: (e, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, kk: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((S, C, f), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xg, wu)
    y = pl.pallas_call(
        _down_kernel,
        grid=(S, C // bc, d // bd, f // bf),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e, i, j, kk: (e, i, kk)),
            pl.BlockSpec((1, bf, bd), lambda e, i, j, kk: (e, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, i, j, kk: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((S, C, d), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(h, wd)
    return y * valid[..., None]


def cache_moe(x: jax.Array, slot_ids: jax.Array, weights: jax.Array,
              wu: jax.Array, wd: jax.Array, wg: Optional[jax.Array] = None,
              *, block_c: int = 128, block_f: int = 512, block_d: int = 512,
              interpret: bool = False) -> jax.Array:
    """x: [T, d]; slot_ids/weights: [T, k]; wu/wg: [S, d, f]; wd: [S, f, d]
    -> y [T, d].  slot_ids < 0 (miss / masked-out wave) contribute zero."""
    T, d = x.shape
    k = slot_ids.shape[1]
    S = wu.shape[0]
    C = _capacity(T * k, block_c)
    M = min(S, T * k)
    if S > M:          # occupancy compaction: grid covers M slots, not S
        slot_ids, wu, wd, wg = compact_occupied_slots(slot_ids, wu, wd, wg, M)
        S = M
    idx, valid, pos = dispatch_to_slots(slot_ids, S, C)
    xg = jnp.take(x, idx.reshape(-1), axis=0).reshape(S, C, d)
    if wg is not None:
        yg = moe_gemm(xg, wg, wu, wd, valid, block_c=block_c,
                      block_f=block_f, block_d=block_d, interpret=interpret)
    else:
        yg = _gelu_grouped(xg, wu, wd, valid, block_c=block_c,
                           block_f=block_f, block_d=block_d,
                           interpret=interpret)
    # combine: read each (token, choice)'s row back from (slot, pos); pos == C
    # lands in the zero-padded lane so skipped choices vanish.
    ygp = jnp.pad(yg, ((0, 0), (0, 1), (0, 0)))
    flat = ygp.reshape(S * (C + 1), d)
    safe = jnp.where(slot_ids >= 0, slot_ids, 0)
    gidx = (safe * (C + 1) + pos).reshape(-1)
    per = jnp.take(flat, gidx, axis=0).reshape(T, k, d)
    w = jnp.where(slot_ids >= 0, weights, 0.0).astype(jnp.float32)
    y = jnp.sum(per.astype(jnp.float32) * w[..., None], axis=1)
    return y.astype(x.dtype)
