"""Mamba2 SSD (state-space dual) chunked-scan Pallas TPU kernel.

Grid: (batch, heads, chunks) with the chunk axis sequential — the inter-chunk
SSM state [P, N] lives in VMEM scratch and is carried across grid steps
(TPU "arbitrary" dimension semantics guarantee in-order execution).

Per chunk (length Q):
  intra  Y  = (C B^T ∘ L) · (dt ⊙ X)        L = exp(segsum(dt·A)) causal
  carry  S' = S·exp(sum dA) + (B·decay)^T (dt ⊙ X)
  inter  Y += C S · exp(cumsum dA)

Oracle: kernels/ref.ssd_ref (single B/C group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # [Q, 1] (padded lane dim)
    a_scalar = a_ref[0]                           # this head's A (scalar)
    B = b_ref[0, 0].astype(jnp.float32)           # [Q, N]
    C = c_ref[0, 0].astype(jnp.float32)           # [Q, N]
    dtv = dt[:, 0]                                # [Q]
    dA = dtv * a_scalar                           # [Q]
    dA_cum = jnp.cumsum(dA)                       # inclusive
    # intra-chunk
    seg = dA_cum[:, None] - dA_cum[None, :]       # [Q, Q]
    qidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(kidx <= qidx, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    xdt = x * dtv[:, None]                        # [Q, P]
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk (uses state BEFORE this chunk)
    state = state_ref[...]                        # [N, P]
    y += jnp.exp(dA_cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # carry state
    decay_to_end = jnp.exp(dA_cum[-1] - dA_cum)   # [Q]
    state_ref[...] = state * jnp.exp(dA_cum[-1]) + jax.lax.dot_general(
        B * decay_to_end[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [N, P]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int, *, interpret: bool = False) -> jax.Array:
    """x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B,C: [b,s,n] -> y [b,s,h,p].

    (Final state is not returned by the kernel path — training/prefill uses
    ssd_ref when the state is needed; see models/mamba.py.)
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    xb = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtb = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk, 1)
    Bb = B.reshape(b, nc, chunk, n)
    Cb = C.reshape(b, nc, chunk, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1,), lambda i, j, c: (j,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, c: (i, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p), lambda i, j, c: (i, j, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xb, dtb, A.astype(jnp.float32), Bb, Cb)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
