"""Grouped expert FFN (MoE GEMM) Pallas TPU kernels.

Operates on capacity-gathered tokens ``xg [E, C, d]`` against per-expert
weights (the hot loop of both routing paths in models/moe.py and of the
SP-MoE offload runtime's cached-expert compute).  Two fused stages:

  stage 1   h = silu(x @ wg) * (x @ wu)     (gate+up fused, one pass over x)
  stage 2   y = h @ wd

Both are blocked [bc × bk × bn] with f32 VMEM accumulators; the expert axis
is the leading parallel grid dim, so on an EP-sharded mesh each core runs its
local experts only.

Oracle: kernels/ref.moe_gemm_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _gate_up_kernel(x_ref, wg_ref, wu_ref, h_ref, accg_ref, accu_ref):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[0]
    accg_ref[...] += jax.lax.dot_general(
        x, wg_ref[0], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        x, wu_ref[0], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(3) - 1)
    def _fin():
        h_ref[0] = (jax.nn.silu(accg_ref[...]) * accu_ref[...]).astype(h_ref.dtype)


def _down_kernel(h_ref, wd_ref, y_ref, acc_ref):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        h_ref[0], wd_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(3) - 1)
    def _fin():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


def moe_gemm(xg: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
             valid: jax.Array, *, block_c: int = 128, block_f: int = 512,
             block_d: int = 512, interpret: bool = False) -> jax.Array:
    """xg: [E,C,d]; wg/wu: [E,d,f]; wd: [E,f,d]; valid: [E,C] -> [E,C,d]."""
    E, C, d = xg.shape
    f = wg.shape[2]
    bc = min(block_c, C)
    bf = min(block_f, f)
    bd = min(block_d, d)
    assert C % bc == 0 and f % bf == 0 and d % bd == 0

    h = pl.pallas_call(
        _gate_up_kernel,
        grid=(E, C // bc, f // bf, d // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32),
                        pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xg, wg, wu)

    y = pl.pallas_call(
        _down_kernel,
        grid=(E, C // bc, d // bd, f // bf),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bf, bd), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(h, wd)
    return y * valid[..., None]
