"""Pure-jnp oracles for every Pallas kernel.  These are the ground truth the
kernels are validated against (tests/test_kernels.py) and double as the
portable fallback path on backends without Pallas.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# flash attention oracle (causal + sliding window + GQA)
# ---------------------------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: [B,Sq,H,D], k/v: [B,Skv,Hkv,D] -> [B,Sq,H,D].  f32 softmax."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, D)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32) / np.sqrt(D)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkrqs,bskd->bqkrd", p, v).reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# decode attention oracle (single query vs long KV)
# ---------------------------------------------------------------------------

def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array) -> jax.Array:
    """q: [B,H,D], k/v: [B,S,Hkv,D], length: [B] valid prefix -> [B,H,D]."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, D)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, k).astype(jnp.float32) / np.sqrt(D)
    valid = jnp.arange(S)[None, :] < length[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkrs,bskd->bkrd", p, v).reshape(B, H, D)


# ---------------------------------------------------------------------------
# grouped expert GEMM oracle (capacity-gathered MoE compute)
# ---------------------------------------------------------------------------

def moe_gemm_ref(xg: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """xg: [E,C,d]; wg/wu: [E,d,f]; wd: [E,f,d]; valid: [E,C] -> [E,C,d].

    SwiGLU expert FFN applied per expert block, invalid slots zeroed.
    """
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg))
    h = h * jnp.einsum("ecd,edf->ecf", xg, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    return y * valid[..., None]


# ---------------------------------------------------------------------------
# slot-indexed cache MoE oracle (SP-MoE verification hot path)
# ---------------------------------------------------------------------------

def cache_moe_ref(x: jax.Array, slot_ids: jax.Array, weights: jax.Array,
                  wu: jax.Array, wd: jax.Array,
                  wg: Optional[jax.Array] = None) -> jax.Array:
    """x: [T, d]; slot_ids/weights: [T, k]; wu/wg: [S, d, f]; wd: [S, f, d]
    -> [T, d].

    Per (token, choice): y += w · FFN_{slot}(x); slot_ids < 0 contribute 0.
    swiglu when wg is given, gelu-up otherwise.

    Ragged grouping: choices are sorted by slot and pushed through
    ``lax.ragged_dot`` against the slot-weight stack — exactly T·k·(3·d·f)
    FLOPs and no weight materialization.  (The previous formulation gathered
    a [T, k, d, f] weight tensor per call, which is prohibitive at full
    model scale — ROADMAP open item, closed.)  Misses are clipped into slot
    0's group and masked out of the combine; a token's choices keep their
    relative order under the stable slot sort, so the per-token f32 sum is
    deterministic and independent of how many other rows share the call.
    """
    T, k = slot_ids.shape
    S = wu.shape[0]
    flat = slot_ids.reshape(-1)                              # [T*k]
    sane = jnp.clip(flat, 0, S - 1)
    order = jnp.argsort(sane, stable=True)
    xs = jnp.take(x, order // k, axis=0)                     # [T*k, d]
    group_sizes = jnp.bincount(sane, length=S).astype(jnp.int32)
    if wg is not None:
        h = jax.nn.silu(jax.lax.ragged_dot(xs, wg, group_sizes))
        h = h * jax.lax.ragged_dot(xs, wu, group_sizes)
    else:
        h = jax.nn.gelu(jax.lax.ragged_dot(xs, wu, group_sizes))
    ys = jax.lax.ragged_dot(h, wd, group_sizes).astype(jnp.float32)
    wf = jnp.where(flat >= 0, weights.reshape(-1), 0.0
                   ).astype(jnp.float32)[order]
    y = jnp.zeros((T, x.shape[1]), jnp.float32).at[order // k].add(
        ys * wf[:, None])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space dual) chunked scan oracle
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., L] -> [..., L, L] with out[i,j] = sum_{j<t<=i} x[t] (causal)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
            B: jax.Array, C: jax.Array, chunk: int,
            init_state: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (Mamba2, alg. 1 of arXiv:2405.21060), single group.

    x: [b,s,h,p]  dt: [b,s,h]  A: [h] (negative)  B,C: [b,s,n]
    Returns (y [b,s,h,p], final_state [b,h,p,n]).  All math in f32.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, n).astype(f32)
    dA = dtc * A.astype(f32)                                   # [b,nc,l,h]
    dA_cum = jnp.cumsum(dA, axis=2)                            # inclusive
    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # [b,nc,h,l,l]
    CB = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)                 # [b,nc,l,l]
    y_diag = jnp.einsum("bcls,bchls,bcsh,bcshp->bclhp",
                        CB, Lmat, dtc, xc)
    # --- chunk states ---
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)      # [b,nc,l,h]
    states = jnp.einsum("bcln,bclh,bclh,bclhp->bchpn",
                        Bc, decay_to_end, dtc, xc)             # [b,nc,h,p,n]
    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                 # [b,nc,h]
    s0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                       # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [b,nc,h,p,n]
    # --- off-diagonal contribution ---
    state_decay = jnp.exp(dA_cum)                              # decay from chunk start
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_ref(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                   B: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent step.  state: [b,h,p,n]; x: [b,h,p];
    dt: [b,h]; A: [h]; B,C: [b,n] -> (y [b,h,p], new_state)."""
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))               # [b,h]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(f32), x.astype(f32), B.astype(f32))
    new = state.astype(f32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, C.astype(f32))
    return y.astype(x.dtype), new.astype(state.dtype)
