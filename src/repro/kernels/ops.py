"""Jit'd public wrappers for the Pallas kernels with automatic backend
dispatch: real Mosaic lowering on TPU, interpret mode elsewhere (bit-accurate
kernel-body execution — how this CPU container validates them), or the pure
jnp oracle via ``impl='ref'``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.cache_moe import cache_moe as _cache_moe
from repro.kernels.moe_gemm import moe_gemm as _moe_gemm
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl"))
def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              impl: str = "auto"):
    """Prefill attention.  impl: auto | kernel | interpret | ref."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("impl",))
def decode_attention(q, k, v, lengths, *, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.decode_attention_ref(q, k, v, lengths)
    return _decode(q, k, v, lengths,
                   interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("impl",))
def moe_gemm(xg, wg, wu, wd, valid, *, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.moe_gemm_ref(xg, wg, wu, wd, valid)
    return _moe_gemm(xg, wg, wu, wd, valid,
                     interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("impl",))
def cache_moe(x, slot_ids, weights, wu, wd, wg=None, *, impl: str = "auto"):
    """Slot-indexed grouped expert FFN over ExpertCache slot buffers
    (SP-MoE verification hot path).  slot_ids < 0 contribute zero."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.cache_moe_ref(x, slot_ids, weights, wu, wd, wg)
    return _cache_moe(x, slot_ids, weights, wu, wd, wg,
                      interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(x, dt, A, B, C, chunk: int, *, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.ssd_ref(x, dt, A, B, C, chunk)[0]
    return _ssd(x, dt, A, B, C, chunk,
                interpret=(impl == "interpret" or not _on_tpu()))
