"""SP-MoE on JAX/TPU: speculative decoding + SD-aware expert prefetching as a
production multi-pod framework.  See README.md / DESIGN.md."""
__version__ = "1.0.0"
