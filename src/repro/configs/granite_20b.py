"""granite-20b — dense llama-arch code model, MQA (kv=1).  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,            # MQA
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    ffn_activation="gelu",   # 2-matrix MLP (GPTBigCode-style; 52L@6144 only sums to ~20B this way)
)
