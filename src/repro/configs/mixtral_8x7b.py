"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088; hf].  Primary SP-MoE paper target."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    ffn_activation="swiglu",
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    sliding_window=4096,       # SWA -> rolling KV cache -> long_500k eligible
)

# SP-MoE draft pairing (paper Table 1): Mistral-7B (dense, same dims, no MoE).
DRAFT_CONFIG = ModelConfig(
    name="mistral-7b-draft",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    ffn_activation="swiglu",
    sliding_window=4096,
)
