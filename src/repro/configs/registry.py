"""``--arch`` id -> ModelConfig registry (assigned pool + paper's own pairs)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig

from repro.configs import (
    granite_20b,
    command_r_35b,
    nemotron_4_340b,
    llama3_2_3b,
    whisper_medium,
    llava_next_mistral_7b,
    deepseek_v2_lite_16b,
    mixtral_8x7b,
    zamba2_7b,
    mamba2_780m,
    phi_3_5_moe,
)

# The 10 assigned architectures (dry-run / roofline matrix).
ASSIGNED: Dict[str, ModelConfig] = {
    "granite-20b": granite_20b.CONFIG,
    "command-r-35b": command_r_35b.CONFIG,
    "nemotron-4-340b": nemotron_4_340b.CONFIG,
    "llama3.2-3b": llama3_2_3b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.CONFIG,
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
}

# Paper-only extras (reproduction benchmarks).
EXTRAS: Dict[str, ModelConfig] = {
    "phi-3.5-moe": phi_3_5_moe.CONFIG,
}

ARCHS: Dict[str, ModelConfig] = {**ASSIGNED, **EXTRAS}

# SP-MoE draft-model pairings (paper Table 1).  The deepseek draft is the
# AWQ-quantized same architecture; in this framework a draft config with the
# same dims stands in (quantization is a numerics detail, not a shape one).
DRAFTS: Dict[str, ModelConfig] = {
    "mixtral-8x7b": mixtral_8x7b.DRAFT_CONFIG,
    "phi-3.5-moe": phi_3_5_moe.DRAFT_CONFIG,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown --arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_draft_config(arch: str) -> Optional[ModelConfig]:
    return DRAFTS.get(arch)


def arch_ids() -> Tuple[str, ...]:
    return tuple(ASSIGNED.keys())
