"""command-r-35b — dense, GQA kv=8, no biases.  [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    ffn_activation="swiglu",
    rope_theta=8e6,
    tie_embeddings=True,       # command-r ties input/output embeddings
)
