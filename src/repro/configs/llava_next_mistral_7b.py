"""llava-next-mistral-7b — mistral-7b backbone + anyres patch-embedding stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    ffn_activation="swiglu",
    num_patches=576,           # base-grid anyres tile, precomputed by stub frontend
)
