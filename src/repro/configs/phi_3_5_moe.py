"""phi-3.5-moe — paper's second target model (16 experts, top-2).  [arXiv:2412.08905]

Not in the assigned pool, but required to reproduce the paper's own tables
(Figures 10/12/14, Table 3).  Draft pairing: Phi-mini-MoE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3.5-moe",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    ffn_activation="swiglu",
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=6400,
)

DRAFT_CONFIG = ModelConfig(
    name="phi-mini-moe-draft",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=960,
    vocab_size=32064,
    ffn_activation="swiglu",
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=960,
)
