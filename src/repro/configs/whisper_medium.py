"""whisper-medium — enc-dec audio; conv frontend stubbed (frame embeddings).
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,             # decoder blocks
    encoder_layers=24,
    encoder_seq=1500,          # precomputed frame embeddings from stub frontend
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,           # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    ffn_activation="gelu",
    tie_embeddings=True,
)
