"""zamba2-7b — hybrid Mamba2 + shared attention blocks.  [arXiv:2411.15242]

Simplification (DESIGN.md): Zamba2 interleaves a *shared-weight* transformer
block (with per-site LoRA deltas) every ~6 Mamba2 blocks; we model the cadence
with a shared attention block every `attn_every` layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,           # shared attn block is MHA
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ffn_activation="swiglu",
    ssm_state_dim=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
)
