"""Config system: model / shape / mesh / run configs + registry.

Every assigned architecture is a `ModelConfig` instance in its own module
under ``repro.configs``; ``repro.configs.registry`` maps ``--arch`` ids to
them.  Configs are plain frozen dataclasses so they can be hashed into jit
static args and serialized into checkpoints.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  Families:

    - ``dense``   decoder-only transformer (llama-style)
    - ``moe``     decoder-only with MoE FFN layers (mixtral / deepseek style)
    - ``ssm``     attention-free Mamba2 (SSD) stack
    - ``hybrid``  Mamba2 blocks with a shared attention block every
                  ``attn_every`` layers (zamba2-style, simplified)
    - ``encdec``  encoder-decoder (whisper); frontend stubbed
    - ``vlm``     dense decoder with prepended patch embeddings (llava stub)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads
    ffn_activation: str = "swiglu"         # swiglu | relu2 | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # SWA window (mixtral: 4096)
    tie_embeddings: bool = False
    attn_logit_softcap: Optional[float] = None

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                      # per-expert ffn dim (0 -> d_ff)
    first_dense_layers: int = 0            # leading dense-FFN layers (deepseek)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001

    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0                    # 0 -> head_dim

    # --- SSM (mamba2 / zamba2) ---
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # --- hybrid ---
    attn_every: int = 6                    # zamba2: shared attn block cadence

    # --- encdec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500                # stub frontend frames

    # --- vlm (llava) ---
    num_patches: int = 0                   # stub frontend patches

    # --- numerics / distribution defaults (overridable per run) ---
    dtype: str = "bfloat16"
    attn_impl: str = "xla"            # xla | kernel (Pallas flash attention
                                      # on TPU; interpret-mode elsewhere)
    remat: bool = True
    remat_policy: str = "full"        # full | selective (save matmul outputs)
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        if self.moe_d_ff == 0 and self.num_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived quantities -------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode: SSM/hybrid state or SWA rolling cache."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def num_moe_layers(self) -> int:
        if not self.is_moe:
            return 0
        return self.num_layers - self.first_dense_layers

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, in order."""
        if self.family == "ssm":
            return ("mamba",) * self.num_layers
        if self.family == "hybrid":
            return tuple(
                "attn" if (i % self.attn_every) == (self.attn_every - 1) else "mamba"
                for i in range(self.num_layers)
            )
        if self.family == "moe":
            return tuple(
                "dense" if i < self.first_dense_layers else "moe"
                for i in range(self.num_layers)
            )
        return ("dense",) * self.num_layers

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=max(2, min(4, self.num_layers)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.family == "hybrid":
            small["num_layers"] = 4
            small["attn_every"] = 2
        if self.is_moe:
            small.update(
                num_experts=min(self.num_experts, 8),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                moe_d_ff=64,
                first_dense_layers=min(self.first_dense_layers, 1),
                num_shared_experts=min(self.num_shared_experts, 1),
            )
        if self.use_mla:
            small.update(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=16,
                         num_kv_heads=4, v_head_dim=16)
        if self.ssm_state_dim:
            small.update(ssm_state_dim=16, ssm_head_dim=16, ssm_chunk=16)
        if self.encoder_layers:
            small.update(encoder_layers=2, encoder_seq=8)
        if self.num_patches:
            small.update(num_patches=4)
        if self.sliding_window:
            small.update(sliding_window=16)
        small.update(over)
        small["name"] = self.name + "-reduced"
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode cache is quadratic-history; skipped per assignment"
    return True, ""


@dataclass(frozen=True)
class RunConfig:
    """Distribution / training knobs resolved per (arch, shape, mesh)."""
    microbatch: int = 0          # 0 -> no grad accumulation (single shot)
    remat: bool = True
    seq_shard_activations: bool = True
    optimizer: str = "adamw"     # adamw | adamw8bit
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: str = "none"   # none | int8_ef
    label_smoothing: float = 0.0
    # serving
    max_decode_len: int = 128
    draft_len: int = 4
