"""nemotron-4-340b — dense, GQA kv=8, squared-ReLU FFN.  [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    ffn_activation="relu2",    # squared ReLU, 2-matrix FFN
)
