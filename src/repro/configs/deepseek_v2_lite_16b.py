"""deepseek-v2-lite-16b — MoE with MLA (kv_lora=512), 64 routed top-6 + 2 shared.
[arXiv:2405.04434; hf]

Assignment note: the pool entry says "MoE 64e top-6 ... 2 shared+160 routed";
real DeepSeek-V2-Lite has 64 routed experts (160 belongs to full V2).  We
follow the `64e top-6` spec — see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,           # MLA: heads share the compressed latent cache
    head_dim=128,              # nope head dim
    d_ff=10944,                # dense FFN of the leading layer
    vocab_size=102400,
    ffn_activation="swiglu",
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,             # v2-lite has no q compression
    rope_head_dim=64,
    v_head_dim=128,
)
