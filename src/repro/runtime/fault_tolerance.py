"""Fault tolerance: heartbeats, straggler detection, restart supervision,
elastic mesh re-planning.

Scale posture (1000+ nodes): training runs under a supervisor that (a)
checkpoints every K steps asynchronously, (b) watches per-step heartbeats,
(c) on failure reforms the mesh from surviving hosts (largest (data, model)
factorization that keeps the model axis intact) and restores the latest
checkpoint with the new shardings, (d) flags stragglers from a step-time
EWMA so the scheduler can evict/replace slow hosts before they become
failures.  The failure itself is injected in tests via FailureInjector; on a
real cluster the same hooks attach to the coordinator's liveness service.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# heartbeat
# ---------------------------------------------------------------------------

class Heartbeat:
    """Per-host liveness: the training loop beats once per step; a monitor
    thread (or the supervisor) checks staleness."""

    def __init__(self, host_id: int, timeout_s: float = 60.0):
        self.host_id = host_id
        self.timeout_s = timeout_s
        self._last = time.monotonic()
        self._lock = threading.Lock()

    def beat(self):
        with self._lock:
            self._last = time.monotonic()

    def alive(self) -> bool:
        with self._lock:
            return (time.monotonic() - self._last) < self.timeout_s


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

@dataclass
class StragglerDetector:
    """EWMA + z-score over per-host step times.  A host whose step time
    exceeds mean + threshold·std for ``patience`` consecutive steps is
    flagged for replacement (mitigation: the supervisor excludes it at the
    next elastic re-plan instead of letting it gate every collective)."""

    alpha: float = 0.2
    threshold: float = 3.0
    patience: int = 3
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    _consecutive: Dict[int, int] = field(default_factory=dict)

    def observe(self, host_id: int, step_time: float) -> bool:
        """Returns True if host is currently flagged as a straggler."""
        if self._n < 5:  # warmup
            self._mean = (self._mean * self._n + step_time) / (self._n + 1)
            self._n += 1
            return False
        z = (step_time - self._mean) / max(np.sqrt(self._var), 1e-6)
        if z > self.threshold:
            # outlier: flag, and keep it OUT of the fleet statistics so a
            # persistent straggler cannot normalize itself into the baseline
            self._consecutive[host_id] = self._consecutive.get(host_id, 0) + 1
        else:
            self._consecutive[host_id] = 0
            self._mean = (1 - self.alpha) * self._mean + self.alpha * step_time
            self._var = (1 - self.alpha) * self._var + \
                self.alpha * (step_time - self._mean) ** 2
            self._n += 1
        return self._consecutive.get(host_id, 0) >= self.patience


# ---------------------------------------------------------------------------
# elastic mesh planning
# ---------------------------------------------------------------------------

def plan_mesh(num_devices: int, model_parallel: int,
              prefer_pods: Optional[int] = None) -> Dict[str, int]:
    """Largest usable (pod, data, model) factorization from surviving
    devices.  The model axis is preserved (weights reshard badly across TP
    degree); data absorbs the loss — standard elastic-DP policy."""
    assert num_devices >= model_parallel, "cannot keep TP degree"
    data = num_devices // model_parallel
    # use the largest power-of-two data degree for clean microbatching
    d2 = 1
    while d2 * 2 <= data:
        d2 *= 2
    out = {"data": d2, "model": model_parallel}
    if prefer_pods and prefer_pods > 1 and d2 % prefer_pods == 0:
        out = {"pod": prefer_pods, "data": d2 // prefer_pods,
               "model": model_parallel}
    return out


# ---------------------------------------------------------------------------
# failure injection + supervisor
# ---------------------------------------------------------------------------

class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail at given
    steps (simulating a host loss) with a device-count after each."""

    def __init__(self, schedule: Dict[int, int]):
        self.schedule = dict(schedule)     # step -> surviving device count

    def check(self, step: int) -> Optional[int]:
        return self.schedule.pop(step, None)


@dataclass
class SupervisorReport:
    restarts: int
    completed_steps: int
    final_devices: int
    straggler_flags: List[int]
    mesh_history: List[Dict[str, int]]


def run_supervised(train_loop: Callable[[int, Dict[str, int], int], Tuple[int, bool]],
                   total_steps: int, initial_devices: int,
                   model_parallel: int,
                   injector: Optional[FailureInjector] = None,
                   max_restarts: int = 10,
                   straggler: Optional[StragglerDetector] = None
                   ) -> SupervisorReport:
    """Generic restart supervisor.

    ``train_loop(start_step, mesh_plan, devices)`` runs until completion or a
    (simulated) failure, returning ``(last_checkpointed_step, finished)`` —
    or ``(last_checkpointed_step, finished, observations)``, where
    ``observations`` is an iterable of ``(host_id, step_time_s)`` pairs fed
    through the :class:`StragglerDetector`.  The supervisor re-plans the mesh
    and restarts from the checkpoint; hosts the detector flags are reported
    in ``straggler_flags`` (previously always ``[]`` — ROADMAP known gap,
    closed).
    """
    devices = initial_devices
    restarts = 0
    step = 0
    detector = straggler if straggler is not None else StragglerDetector()
    flagged: set = set()
    mesh_history = [plan_mesh(devices, model_parallel)]

    def _step(start: int, plan: Dict[str, int], dev: int) -> Tuple[int, bool]:
        out = train_loop(start, plan, dev)
        if len(out) == 3:                  # (step, finished, observations)
            s, fin, obs = out
            for host_id, step_time in obs:
                if detector.observe(int(host_id), float(step_time)):
                    flagged.add(int(host_id))
            return s, fin
        return out

    while step < total_steps and restarts <= max_restarts:
        plan = plan_mesh(devices, model_parallel)
        if plan != mesh_history[-1]:
            mesh_history.append(plan)
        step, finished = _step(step, plan, devices)
        if finished:
            return SupervisorReport(restarts, step, devices, sorted(flagged),
                                    mesh_history)
        restarts += 1
        if injector:
            surv = injector.check(step)
            if surv is not None:
                devices = surv
    return SupervisorReport(restarts, step, devices, sorted(flagged),
                            mesh_history)
