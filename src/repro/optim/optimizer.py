"""Optimizers: AdamW (f32 states) and AdamW8bit (block-quantized int8 states
with per-block f32 scales — 4x optimizer-memory saving, the knob that lets
nemotron-4-340b train on a v5e pod), plus warmup+cosine schedule and global
gradient clipping.  Pure pytree-functional, pjit-friendly (states inherit the
param shardings; quantized states shard identically since blocks are along
the last dim).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# int8 block quantization (shared by AdamW8bit and gradient compression)
# ---------------------------------------------------------------------------

QBLOCK = 256


def quantize_i8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (int8 values, f32 per-block scales); blocks along flattened dim."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % QBLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return q, scale


def dequantize_i8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = int(np.prod(shape))
    return flat[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def update(self, grads, state: AdamState, params):
        grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        lr = self.lr_fn(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * gf
            v2 = self.b2 * v + (1 - self.b2) * gf * gf
            u = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p - lr * u.astype(jnp.float32)).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamState(step, new_m, new_v), {
            "grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# AdamW with 8-bit states
# ---------------------------------------------------------------------------

class Adam8bitState(NamedTuple):
    step: jax.Array
    m_q: Any
    m_s: Any
    v_q: Any
    v_s: Any


@dataclass(frozen=True)
class AdamW8bit:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> Adam8bitState:
        qs = jax.tree.map(lambda p: quantize_i8(jnp.zeros(p.shape, jnp.float32)),
                          params)
        mq = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
        ms = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
        return Adam8bitState(jnp.zeros((), jnp.int32), mq, ms,
                             jax.tree.map(jnp.copy, mq), jax.tree.map(jnp.copy, ms))

    def update(self, grads, state: Adam8bitState, params):
        grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        lr = self.lr_fn(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, mq, ms, vq, vs, p):
            gf = g.astype(jnp.float32)
            m = dequantize_i8(mq, ms, p.shape)
            v = dequantize_i8(vq, vs, p.shape)
            m2 = self.b1 * m + (1 - self.b1) * gf
            v2 = self.b2 * v + (1 - self.b2) * gf * gf
            u = (m2 / b1c) / (jnp.sqrt(jnp.maximum(v2, 0.0) / b2c) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            p2 = (p - lr * u).astype(p.dtype)
            mq2, ms2 = quantize_i8(m2)
            vq2, vs2 = quantize_i8(v2)
            return p2, mq2, ms2, vq2, vs2

        out = jax.tree.map(upd, grads, state.m_q, state.m_s, state.v_q,
                           state.v_s, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), Adam8bitState(step, pick(1), pick(2), pick(3), pick(4)), {
            "grad_norm": gnorm, "lr": lr}


def make_optimizer(name: str, lr_fn, weight_decay: float = 0.1,
                   grad_clip: float = 1.0):
    if name == "adamw":
        return AdamW(lr_fn, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "adamw8bit":
        return AdamW8bit(lr_fn, weight_decay=weight_decay, grad_clip=grad_clip)
    raise ValueError(name)
