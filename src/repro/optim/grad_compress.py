"""Error-feedback int8 gradient compression for the data-parallel axis.

At 1000+ node scale the DP gradient reduce-scatter dominates the training
collective term; compressing the per-shard gradient contribution to int8
(block scales) cuts those bytes 2x vs bf16 / 4x vs f32.  Error feedback
(residual accumulation) keeps convergence — the quantization error of step t
is added back into the gradient of step t+1 (Karimireddy et al., 2019).

In pjit-land the all-reduce itself is XLA-inserted; this module provides the
quantize→dequantize+EF transform applied to the LOCAL gradient contribution
before the reduction (numerically identical placement to a custom collective
at the mesh boundary), plus the byte-savings accounting used by the roofline.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizer import dequantize_i8, quantize_i8


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_error_feedback(grads, ef_state
                                 ) -> Tuple[Any, Any]:
    """grads -> (compressed-roundtrip grads, new error-feedback residuals)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_i8(gf)
        deq = dequantize_i8(q, s, gf.shape)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, ef_state)
    newg = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe


def compressed_bytes_fraction() -> float:
    """int8 + per-256 f32 scale vs f32: (1 + 4/256) / 4."""
    return (1.0 + 4.0 / 256.0) / 4.0
