"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract memory / cost / collective statistics.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, OOM-at-compile, or unsupported collective
fails the cell.  Run:

    PYTHONPATH=src python -m repro.launch.dryrun                  # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape decode_32k --multi-pod both --out results.json
"""
# The dry-run (and ONLY the dry-run) fabricates 512 host devices so
# jax.make_mesh can build the production mesh.  MUST precede any jax import.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ALL_SHAPES, SHAPES, RunConfig, ShapeConfig,
                                shape_applicable)
from repro.configs.registry import ASSIGNED, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as SH
from repro.models.costmodel import roofline_terms
from repro.models.registry import build_model, decode_input_specs, input_specs
from repro.models.train import make_train_step
from repro.optim.optimizer import make_optimizer, warmup_cosine

# matches `%name = <shape> <op>(...)` — the op is on the RHS (instruction
# names may use underscores, e.g. %all_gather.24 = f32[...] all-gather(...))
_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9]+\[[0-9,]*\])[^\n]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Per-device, and scan bodies appear once (XLA does not unroll) — the
    analytical model in models/costmodel.py provides trip-count-scaled
    totals; this parse proves which collectives the partitioner inserted.
    """
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, op = m.group(1), m.group(2)
        sm = _SHAPE_RE.match(shape_s)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        n = int(np.prod([int(x) for x in dims.split(",") if x])) if dims else 1
        nbytes = n * _DTYPE_BYTES.get(dt, 4)
        rec = out.setdefault(op, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += float(nbytes)
    return out


def _batch_shard(mesh, specs: Dict[str, Any]) -> Dict[str, Any]:
    """Batch inputs sharded over (pod, data) when the dim divides (long_500k
    has global_batch=1 -> replicated)."""
    out = {}
    for k, v in specs.items():
        dp = SH._fit(v.shape[0], mesh, SH.data_axes(mesh))
        out[k] = NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
    return out


def plan_microbatch(cfg, shape, mesh) -> int:
    """Gradient-accumulation depth so per-microbatch activations fit HBM."""
    dp = SH.mesh_axis_size(mesh, SH.data_axes(mesh))
    b_local = max(shape.global_batch // dp, 1)
    model = SH.mesh_axis_size(mesh, "model")
    seq_div = model if cfg.d_model % model == 0 else 1
    layers = cfg.num_layers + (cfg.encoder_layers or 0)
    per_sample = shape.seq_len * cfg.d_model * 2 * layers / seq_div
    budget = 4e9
    micro = 1
    while micro < b_local and (b_local / micro) * per_sample > budget:
        micro *= 2
    return micro


def lower_cell(arch: str, shape_name: str, mesh, *, optimizer: str = "adamw",
               weight_gather: Optional[bool] = None, verify_block: int = 1,
               capacity_factor: Optional[float] = None,
               remat_override: Optional[bool] = None,
               remat_policy: Optional[str] = None,
               seq_parallel: bool = False,
               extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) cell.

    The keyword knobs are the §Perf hillclimb levers: ``weight_gather``
    (ZeRO-style serving), ``verify_block`` (SD verification block size for
    decode cells — the paper's technique in production form),
    ``capacity_factor`` / ``remat_override`` (training efficiency knobs).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if capacity_factor is not None:
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    if remat_override is not None:
        cfg = _dc.replace(cfg, remat=remat_override)
    if remat_policy is not None:
        cfg = _dc.replace(cfg, remat_policy=remat_policy)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    model = build_model(cfg)
    t0 = time.time()
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mode = "train" if shape.kind == "train" else "serve"
    pspecs = SH.param_pspecs(cfg, params_shapes, mesh, mode=mode,
                             weight_gather=weight_gather)
    pshard = SH.to_shardings(mesh, pspecs)
    dp = P(SH.data_axes(mesh))

    if shape.kind == "train":
        micro = plan_microbatch(cfg, shape, mesh)
        run = RunConfig(microbatch=micro, optimizer=optimizer)
        opt = make_optimizer(optimizer, warmup_cosine(3e-4, 100, 10000))
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        # optimizer states mirror the param shardings; the step counter is
        # replicated.  (AdamState = (step, m, v).)
        opt_specs = opt_shapes.__class__(P(), pspecs, pspecs)
        opt_shard = SH.to_shardings(mesh, opt_specs)
        step_fn = make_train_step(model, cfg, run, opt)
        ispecs = input_specs(cfg, shape)
        batch_shard = _batch_shard(mesh, ispecs)
        with mesh:
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, opt_shard, batch_shard),
                             out_shardings=(pshard, opt_shard, None))
            lowered = jitted.lower(params_shapes, opt_shapes, ispecs)
            compiled = lowered.compile()
        fn_desc = f"train_step(micro={micro})"
    elif shape.kind == "prefill" and seq_parallel and cfg.family == "ssm":
        from repro.models.mamba_sp import seq_parallel_forward
        ispecs = input_specs(cfg, shape)
        # weights fully replicated; sequence sharded over the model axis
        repl = jax.tree.map(lambda _: P(), params_shapes)
        pshard = SH.to_shardings(mesh, repl)
        tokshard = NamedSharding(mesh, P(SH.data_axes(mesh), "model"))

        def prefill_fn(params, tokens):
            return seq_parallel_forward(params, tokens, cfg, mesh)

        with mesh:
            jitted = jax.jit(prefill_fn, in_shardings=(pshard, tokshard),
                             out_shardings=None)
            lowered = jitted.lower(params_shapes, ispecs["tokens"])
            compiled = lowered.compile()
        fn_desc = "prefill_forward(seq_parallel)"
    elif shape.kind == "prefill":
        ispecs = input_specs(cfg, shape)
        batch_shard = _batch_shard(mesh, ispecs)

        if cfg.family == "encdec":
            def prefill_fn(params, tokens, frames):
                logits, _ = model.forward(params, tokens, frames)
                return logits[:, -1]
            args = (params_shapes, ispecs["tokens"], ispecs["frames"])
            ishard = (pshard, batch_shard["tokens"], batch_shard["frames"])
        elif cfg.family == "vlm":
            def prefill_fn(params, tokens, patches):
                logits, _ = model.forward(params, tokens, patch_embeds=patches)
                return logits[:, -1]
            args = (params_shapes, ispecs["tokens"], ispecs["patch_embeds"])
            ishard = (pshard, batch_shard["tokens"], batch_shard["patch_embeds"])
        else:
            def prefill_fn(params, tokens):
                logits, _ = model.forward(params, tokens)
                return logits[:, -1]
            args = (params_shapes, ispecs["tokens"])
            ishard = (pshard, batch_shard["tokens"])
        with mesh:
            jitted = jax.jit(prefill_fn, in_shardings=ishard, out_shardings=None)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        fn_desc = "prefill_forward"
    else:  # decode
        dspecs = decode_input_specs(cfg, shape)
        if verify_block > 1:   # SD verification block: Sq tokens per step
            B = dspecs["tokens"].shape[0]
            dspecs["tokens"] = jax.ShapeDtypeStruct((B, verify_block),
                                                    jnp.int32)
        cache_specs = SH.cache_pspecs(cfg, dspecs["cache"], mesh)
        cache_shard = SH.to_shardings(mesh, cache_specs)
        tok_shard = _batch_shard(mesh, {"tokens": dspecs["tokens"]})["tokens"]

        def serve_step(params, cache, tokens, pos):
            logits, new_cache, _ = model.decode_step(params, cache, tokens, pos)
            return logits, new_cache

        with mesh:
            jitted = jax.jit(serve_step,
                             in_shardings=(pshard, cache_shard, tok_shard, None),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, dspecs["cache"],
                                   dspecs["tokens"], dspecs["pos"])
            compiled = lowered.compile()
        fn_desc = f"serve_step(block={verify_block})"

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    if weight_gather is None:    # mirror param_pspecs' serve auto-decision
        total_b = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree.leaves(params_shapes))
        weight_gather = (mode == "serve" and
                         total_b / mesh_shape.get("model", 1) > 10e9)
    analytical = roofline_terms(cfg, shape, mesh_shape, mode,
                                weight_gather=weight_gather,
                                verify_block=verify_block,
                                capacity_factor=capacity_factor,
                                remat=remat_override)
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok", "fn": fn_desc,
        "mesh": mesh_shape,
        "weight_gather": bool(weight_gather),
        "verify_block": verify_block,
        "capacity_factor": capacity_factor,
        "remat_override": remat_override,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        "xla_cost": {"flops_per_device_body": cost.get("flops", 0.0),
                     "bytes_per_device_body": cost.get("bytes accessed", 0.0)},
        "hlo_collectives": colls,
        "roofline": analytical,
    }
    if extra:
        rec.update(extra)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"), default="both")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--out", default="dryrun_results.json")
    # §Perf hillclimb knobs
    ap.add_argument("--weight-gather", choices=("auto", "on", "off"),
                    default="auto")
    ap.add_argument("--verify-block", type=int, default=1)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", choices=("full", "selective"),
                    default=None)
    ap.add_argument("--seq-parallel", action="store_true",
                    help="ssm prefill: sequence-parallel mamba (replicated "
                         "weights, sharded sequence, state handoff)")
    ap.add_argument("--tag", default=None, help="label stored in the record")
    args = ap.parse_args()
    wg = {"auto": None, "on": True, "off": False}[args.weight_gather]

    archs = list(ASSIGNED) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in ALL_SHAPES] if args.shape == "all"
              else args.shape.split(","))
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], json.dumps(r.get("mesh", {}), sort_keys=True),
             r.get("tag")) for r in results}

    for multi_pod in pods:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_key = json.dumps(dict(zip(mesh.axis_names, mesh.devices.shape)),
                              sort_keys=True)
        for arch in archs:
            for shape in shapes:
                if (arch, shape, mesh_key, args.tag) in done:
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_key} ...", flush=True)
                try:
                    rec = lower_cell(
                        arch, shape, mesh, optimizer=args.optimizer,
                        weight_gather=wg, verify_block=args.verify_block,
                        capacity_factor=args.capacity_factor,
                        remat_override=(False if args.no_remat else None),
                        remat_policy=args.remat_policy,
                        seq_parallel=args.seq_parallel,
                        extra={"tag": args.tag} if args.tag else None)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=float)
                status = rec["status"]
                if status == "ok":
                    m = rec["memory"]["peak_per_device"] / 1e9
                    print(f"  OK peak/device={m:.2f} GB "
                          f"dominant={rec['roofline']['dominant']} "
                          f"({rec['compile_s']}s)", flush=True)
                else:
                    print(f"  {status.upper()}: {rec.get('reason', rec.get('error'))}",
                          flush=True)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_err = sum(1 for r in results if r["status"] == "error")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
