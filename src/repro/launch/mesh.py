"""Mesh construction.  ``make_production_mesh`` is a FUNCTION (never a
module-level constant) so importing this module touches no jax device state.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: make_mesh has no axis_types kwarg (all Auto)
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_from_plan(plan: Dict[str, int]) -> Mesh:
    """Mesh from an elastic re-plan (runtime/fault_tolerance.plan_mesh)."""
    axes = tuple(a for a in ("pod", "data", "model") if a in plan)
    shape = tuple(plan[a] for a in axes)
    return _make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return _make_mesh((1, 1), ("data", "model"))
