"""Training driver: data pipeline -> pjit train step -> checkpoint ->
fault-tolerant supervision.

On real hardware this runs one process per host under the supervisor; on
this container it drives reduced configs end-to-end on the CPU device (see
examples/train_lm.py) and full configs through the dry-run.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import make_pipeline
from repro.launch.mesh import single_device_mesh
from repro.models import sharding as SH
from repro.models.registry import build_model
from repro.models.train import make_train_step
from repro.optim.optimizer import make_optimizer, warmup_cosine
from repro.runtime.fault_tolerance import Heartbeat, StragglerDetector


class Trainer:
    def __init__(self, cfg, shape: ShapeConfig, run: RunConfig, mesh=None,
                 ckpt_dir: Optional[str] = None, grad_compress: bool = False):
        self.cfg, self.shape, self.run = cfg, shape, run
        self.mesh = mesh if mesh is not None else single_device_mesh()
        self.model = build_model(cfg)
        self.opt = make_optimizer(
            run.optimizer, warmup_cosine(run.learning_rate, run.warmup_steps,
                                         run.total_steps),
            weight_decay=run.weight_decay, grad_clip=run.grad_clip)
        self.grad_compress = grad_compress
        step_fn = make_train_step(self.model, cfg, run, self.opt,
                                  grad_compress=grad_compress)
        pshapes = jax.eval_shape(lambda: self.model.init(jax.random.PRNGKey(0)))
        pspecs = SH.param_pspecs(cfg, pshapes, self.mesh, mode="train")
        self.pshard = SH.to_shardings(self.mesh, pspecs)
        self.step_fn = jax.jit(step_fn)
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.heartbeat = Heartbeat(host_id=0)
        self.straggler = StragglerDetector()

    def init_state(self, seed: int = 0):
        with self.mesh:
            params = jax.jit(self.model.init, out_shardings=self.pshard)(
                jax.random.PRNGKey(seed))
            opt_state = self.opt.init(params)
        state: Dict[str, Any] = {"params": params, "opt": opt_state}
        if self.grad_compress:
            from repro.optim.grad_compress import init_error_feedback
            state["ef"] = init_error_feedback(params)
        return state

    def restore_or_init(self, seed: int = 0):
        state = self.init_state(seed)
        start = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state = self.ckpt.restore(latest, state)
                start = latest
        return start, state

    def train(self, steps: int, ckpt_every: int = 0, seed: int = 0,
              fail_at: Optional[int] = None, log_every: int = 10):
        start, state = self.restore_or_init(seed)
        pipe = make_pipeline(self.cfg, self.shape, seed=seed, start_step=start)
        losses = []
        try:
            for step in range(start, steps):
                if fail_at is not None and step == fail_at:
                    raise RuntimeError(f"injected failure at step {step}")
                batch = next(pipe)
                batch = jax.tree.map(jnp.asarray, batch)
                t0 = time.perf_counter()
                if self.grad_compress:
                    params, opt, ef, metrics = self.step_fn(
                        state["params"], state["opt"], batch, state["ef"])
                    state = {"params": params, "opt": opt, "ef": ef}
                else:
                    params, opt, metrics = self.step_fn(
                        state["params"], state["opt"], batch)
                    state = {"params": params, "opt": opt}
                loss = float(metrics["loss"])
                losses.append(loss)
                self.heartbeat.beat()
                self.straggler.observe(0, time.perf_counter() - t0)
                if ckpt_every and self.ckpt and (step + 1) % ckpt_every == 0:
                    self.ckpt.save(step + 1, state)
                if log_every and step % log_every == 0:
                    print(f"step {step} loss {loss:.4f} "
                          f"lr {float(metrics['lr']):.2e}", flush=True)
        finally:
            pipe.close()
            if self.ckpt:
                self.ckpt.wait()
        return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(optimizer=args.optimizer, warmup_steps=5,
                    total_steps=args.steps)
    tr = Trainer(cfg, shape, run, ckpt_dir=args.ckpt_dir,
                 grad_compress=args.grad_compress)
    _, losses = tr.train(args.steps, ckpt_every=args.ckpt_every)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
