"""Serving driver for the unified request-level API (core/engine.py).

Policy is two-axis: ``--decode`` picks how tokens are committed (greedy |
sd | sd-adaptive), ``--offload`` picks where expert weights live (none |
spmoe | adapmoe | moe-infinity | on-demand).  Any combination is valid and
lossless; offload policies require an MoE target.  The legacy single-axis
``--policy`` flag is kept as a deprecated alias (``sd-only`` ->
``--decode sd --offload none``, ``spmoe`` -> ``--decode sd --offload
spmoe``, ...).

One Engine serves all ``--requests`` requests, so request 2+ hits a warm
expert cache (watch ``hit_rate`` climb).  ``--concurrency N`` decodes up
to N requests at once on that one warm cache — each scheduling round
batches the ready sessions' verify blocks into ONE fused kernel launch
(one routing pass, ≤2 host syncs per round instead of 2 per session), and
every stream stays bit-identical to serving it alone.  ``--stream`` prints
tokens as each verify block commits (prefixed with the request id when
concurrent); ``--stop-token`` ends a request early on every decode x
offload combination identically.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --decode sd --offload spmoe --tokens 32 --requests 2

    # four requests, two decoded concurrently per turn
    PYTHONPATH=src python -m repro.launch.serve --requests 4 --concurrency 2
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config, get_draft_config
from repro.core.engine import (DECODE_POLICIES, OFFLOAD_POLICIES, Engine,
                               EngineConfig, Request, derive_draft_config)

# legacy --policy values -> (decode, offload)
LEGACY_POLICY = {
    "greedy": ("greedy", "none"),
    "sd-only": ("sd", "none"),
    "sd-adaptive": ("sd-adaptive", "none"),
    "spmoe": ("sd", "spmoe"),
    "adapmoe": ("sd", "adapmoe"),
    "moe-infinity": ("sd", "moe-infinity"),
    "on-demand": ("sd", "on-demand"),
}


def reduced_pair(arch: str):
    cfg = get_config(arch).reduced(dtype="float32")
    draft = get_draft_config(arch)
    if draft is not None and draft.name != cfg.name:
        dcfg = draft.reduced(dtype="float32")
    else:
        dcfg = derive_draft_config(cfg)
    return cfg, dcfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--decode", default=None, choices=DECODE_POLICIES,
                    help="token-commit policy (default: sd)")
    ap.add_argument("--offload", default=None, choices=OFFLOAD_POLICIES,
                    help="expert-weight policy (default: spmoe for MoE)")
    ap.add_argument("--policy", default=None, choices=sorted(LEGACY_POLICY),
                    help="DEPRECATED single-axis alias for --decode/--offload")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="requests decoded concurrently on the one warm "
                         "cache (1 = serial).  Each scheduling round "
                         "batches the ready sessions' verify blocks into "
                         "ONE fused kernel launch — one routing pass and "
                         "<=2 host syncs per round instead of per session "
                         "— while every stream stays bit-identical to "
                         "serving it alone")
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--cache-slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--stop-token", type=int, action="append", default=None)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as verify blocks commit")
    args = ap.parse_args()

    decode, offload = args.decode, args.offload
    if args.policy is not None:
        if decode or offload:
            ap.error("--policy is an alias; don't mix with --decode/--offload")
        decode, offload = LEGACY_POLICY[args.policy]
        print(f"# --policy {args.policy} is deprecated; use "
              f"--decode {decode} --offload {offload}")
    cfg, dcfg = reduced_pair(args.arch)
    if decode is None:
        decode = "sd"
    if offload is None:
        offload = "spmoe" if cfg.is_moe else "none"

    max_seq = args.prompt_len + args.tokens + max(args.draft_len, 8) + 8
    config = EngineConfig(model=cfg, draft=dcfg, decode=decode,
                          offload=offload, cache_slots=args.cache_slots,
                          draft_len=args.draft_len, max_seq=max_seq)
    prompts = [jax.random.randint(jax.random.PRNGKey(2 + i),
                                  (1, args.prompt_len), 0, cfg.vocab_size)
               for i in range(args.requests)]
    reqs = [Request(prompt=prompt, max_new_tokens=args.tokens,
                    stop_tokens=args.stop_token or (),
                    request_id=f"req-{i}")
            for i, prompt in enumerate(prompts)]

    def report(res):
        print(f"[{res.request_id}] finish={res.finish_reason}")
        for k, v in sorted(res.metrics.as_dict().items()):
            print(f"    {k}: {v}")

    with Engine(config) as eng:
        if args.concurrency > 1:
            if args.stream:
                for rid, tok in eng.serve(reqs, concurrency=args.concurrency):
                    print(f"{rid}:{tok}", end=" ", flush=True)
                print()
                results = eng.last_batch
            else:
                results = eng.serve_all(reqs, concurrency=args.concurrency)
            for res in results:
                if not args.stream:
                    print(f"[{res.request_id}] tokens: {res.tokens}")
                report(res)
        else:
            for req in reqs:
                if args.stream:
                    print(f"[{req.request_id}] tokens:", end=" ", flush=True)
                    for tok in eng.stream(req):
                        print(tok, end=" ", flush=True)
                    print()
                    res = eng.last_result
                else:
                    res = eng.submit(req)
                    print(f"[{req.request_id}] tokens: {res.tokens}")
                report(res)
        cum = eng.metrics()
        print(f"cumulative: requests={cum.requests} tokens={cum.tokens} "
              f"hit_rate={cum.hit_rate:.3f} tpot={cum.tpot_wall * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
