"""Serving driver for the unified request-level API (core/engine.py).

Policy is two-axis: ``--decode`` picks how tokens are committed (greedy |
sd | sd-adaptive), ``--offload`` picks where expert weights live (none |
spmoe | adapmoe | moe-infinity | on-demand).  Any combination is valid and
lossless; offload policies require an MoE target.  The legacy single-axis
``--policy`` flag is kept as a deprecated alias (``sd-only`` ->
``--decode sd --offload none``, ``spmoe`` -> ``--decode sd --offload
spmoe``, ...).

One Engine serves all ``--requests`` requests, so request 2+ hits a warm
expert cache (watch ``hit_rate`` climb).  ``--concurrency N`` decodes up
to N requests at once on that one warm cache — each scheduling round
batches the ready sessions' verify blocks into ONE fused kernel launch
(one routing pass, ≤2 host syncs per round instead of 2 per session), and
every stream stays bit-identical to serving it alone.  ``--stream`` prints
tokens as each verify block commits (prefixed with the request id when
concurrent); ``--stop-token`` ends a request early on every decode x
offload combination identically.

Chaos hardening: ``--chaos`` turns on the seeded fault injector
(core/chaos.py) against the expert I/O plane — transient fetch/insert
errors, latency spikes, payload corruption, prefetch-worker kills — tuned
with the ``--chaos-*`` rates.  Serving stays lossless (retry +
checksum-quarantine + the graceful-degradation ladder absorb every injected
fault); the per-request report grows the resilience counters
(``prefetch_errors`` / ``prefetch_retries`` / ``checksum_failures`` /
``worker_restarts`` / ``degraded_rounds`` / ``io_errors``) and the footer
prints the engine's final health.  ``--deadline-s`` arms a per-request
wall-clock budget (``finish_reason="deadline"`` when it expires).

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --decode sd --offload spmoe --tokens 32 --requests 2

    # four requests, two decoded concurrently per turn
    PYTHONPATH=src python -m repro.launch.serve --requests 4 --concurrency 2

    # chaos drill: 10% fetch faults + corruption + worker kills, still lossless
    PYTHONPATH=src python -m repro.launch.serve --requests 4 --concurrency 2 \
        --chaos --chaos-fetch-error-rate 0.1 --chaos-corrupt-rate 0.05 \
        --chaos-kill-every 5
"""
from __future__ import annotations

import argparse

import jax

from repro.core.chaos import ChaosConfig
from repro.configs.registry import get_config, get_draft_config
from repro.core.engine import (DECODE_POLICIES, OFFLOAD_POLICIES, Engine,
                               EngineConfig, Request, derive_draft_config)

# legacy --policy values -> (decode, offload)
LEGACY_POLICY = {
    "greedy": ("greedy", "none"),
    "sd-only": ("sd", "none"),
    "sd-adaptive": ("sd-adaptive", "none"),
    "spmoe": ("sd", "spmoe"),
    "adapmoe": ("sd", "adapmoe"),
    "moe-infinity": ("sd", "moe-infinity"),
    "on-demand": ("sd", "on-demand"),
}


def reduced_pair(arch: str):
    cfg = get_config(arch).reduced(dtype="float32")
    draft = get_draft_config(arch)
    if draft is not None and draft.name != cfg.name:
        dcfg = draft.reduced(dtype="float32")
    else:
        dcfg = derive_draft_config(cfg)
    return cfg, dcfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--decode", default=None, choices=DECODE_POLICIES,
                    help="token-commit policy (default: sd)")
    ap.add_argument("--offload", default=None, choices=OFFLOAD_POLICIES,
                    help="expert-weight policy (default: spmoe for MoE)")
    ap.add_argument("--policy", default=None, choices=sorted(LEGACY_POLICY),
                    help="DEPRECATED single-axis alias for --decode/--offload")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="requests decoded concurrently on the one warm "
                         "cache (1 = serial).  Each scheduling round "
                         "batches the ready sessions' verify blocks into "
                         "ONE fused kernel launch — one routing pass and "
                         "<=2 host syncs per round instead of per session "
                         "— while every stream stays bit-identical to "
                         "serving it alone")
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--cache-slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--stop-token", type=int, action="append", default=None)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as verify blocks commit")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget; an expired request "
                         "retires with finish_reason=deadline")
    chz = ap.add_argument_group(
        "chaos", "seeded fault injection against the expert I/O plane "
                 "(lossless by construction: retries, checksum quarantine "
                 "and the degradation ladder absorb every injected fault)")
    chz.add_argument("--chaos", action="store_true",
                     help="enable the fault injector (core/chaos.py)")
    chz.add_argument("--chaos-seed", type=int, default=0)
    chz.add_argument("--chaos-fetch-error-rate", type=float, default=0.1,
                     help="P(transient error) per HostExpertStore.fetch")
    chz.add_argument("--chaos-insert-error-rate", type=float, default=0.0,
                     help="P(transient error) per ExpertCache.insert")
    chz.add_argument("--chaos-spike-rate", type=float, default=0.0,
                     help="P(latency spike) per fetch")
    chz.add_argument("--chaos-spike-ms", type=float, default=10.0,
                     help="latency-spike duration (milliseconds)")
    chz.add_argument("--chaos-corrupt-rate", type=float, default=0.0,
                     help="P(staged-payload byte flip) per fetch — caught "
                          "by checksum verification, never inserted")
    chz.add_argument("--chaos-kill-every", type=int, default=0,
                     help="kill the prefetch worker every Nth task "
                          "(0 = never); the supervisor restarts it")
    args = ap.parse_args()

    decode, offload = args.decode, args.offload
    if args.policy is not None:
        if decode or offload:
            ap.error("--policy is an alias; don't mix with --decode/--offload")
        decode, offload = LEGACY_POLICY[args.policy]
        print(f"# --policy {args.policy} is deprecated; use "
              f"--decode {decode} --offload {offload}")
    cfg, dcfg = reduced_pair(args.arch)
    if decode is None:
        decode = "sd"
    if offload is None:
        offload = "spmoe" if cfg.is_moe else "none"

    chaos = None
    if args.chaos:
        chaos = ChaosConfig(
            seed=args.chaos_seed,
            fetch_error_rate=args.chaos_fetch_error_rate,
            insert_error_rate=args.chaos_insert_error_rate,
            spike_rate=args.chaos_spike_rate,
            spike_s=args.chaos_spike_ms / 1e3,
            corrupt_rate=args.chaos_corrupt_rate,
            kill_worker_every=args.chaos_kill_every)
    max_seq = args.prompt_len + args.tokens + max(args.draft_len, 8) + 8
    config = EngineConfig(model=cfg, draft=dcfg, decode=decode,
                          offload=offload, cache_slots=args.cache_slots,
                          draft_len=args.draft_len, max_seq=max_seq,
                          chaos=chaos)
    prompts = [jax.random.randint(jax.random.PRNGKey(2 + i),
                                  (1, args.prompt_len), 0, cfg.vocab_size)
               for i in range(args.requests)]
    reqs = [Request(prompt=prompt, max_new_tokens=args.tokens,
                    stop_tokens=args.stop_token or (),
                    deadline_s=args.deadline_s,
                    request_id=f"req-{i}")
            for i, prompt in enumerate(prompts)]

    def report(res):
        print(f"[{res.request_id}] finish={res.finish_reason}")
        for k, v in sorted(res.metrics.as_dict().items()):
            print(f"    {k}: {v}")

    with Engine(config) as eng:
        if args.concurrency > 1:
            if args.stream:
                for rid, tok in eng.serve(reqs, concurrency=args.concurrency):
                    print(f"{rid}:{tok}", end=" ", flush=True)
                print()
                results = eng.last_batch
            else:
                results = eng.serve_all(reqs, concurrency=args.concurrency)
            for res in results:
                if not args.stream:
                    print(f"[{res.request_id}] tokens: {res.tokens}")
                report(res)
        else:
            for req in reqs:
                if args.stream:
                    print(f"[{req.request_id}] tokens:", end=" ", flush=True)
                    for tok in eng.stream(req):
                        print(tok, end=" ", flush=True)
                    print()
                    res = eng.last_result
                else:
                    res = eng.submit(req)
                    print(f"[{req.request_id}] tokens: {res.tokens}")
                report(res)
        cum = eng.metrics()
        print(f"cumulative: requests={cum.requests} tokens={cum.tokens} "
              f"hit_rate={cum.hit_rate:.3f} tpot={cum.tpot_wall * 1e3:.1f}ms")
        if eng.runtime is not None:
            # runtime counters, not the Metrics ledger: worker-thread
            # increments landing between turn windows still show up here
            c = eng.runtime.counters()
            print(f"health: {eng.runtime.health()} "
                  f"(prefetch_errors={c['prefetch_errors']} "
                  f"retries={c['prefetch_retries']} "
                  f"checksum_failures={c['checksum_failures']} "
                  f"worker_restarts={c['worker_restarts']} "
                  f"degraded_rounds={c['degraded_rounds']} "
                  f"io_errors={c['io_errors']})")
            if args.chaos and eng.runtime.chaos is not None:
                inj = eng.runtime.chaos.injected
                print("chaos injected:", " ".join(
                    f"{k}={v}" for k, v in sorted(inj.items())))


if __name__ == "__main__":
    main()
