"""Serving driver: SP-MoE offload engine (paper mode) or plain SD serving.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --policy spmoe --tokens 32
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_draft_config
from repro.core.runtime import OffloadEngine
from repro.core.sd import greedy_generate, sd_generate
from repro.models.registry import build_model


def reduced_pair(arch: str):
    cfg = get_config(arch).reduced(dtype="float32")
    draft = get_draft_config(arch)
    if draft is not None and draft.name != cfg.name:
        dcfg = draft.reduced(dtype="float32")
    elif cfg.is_moe:
        dcfg = dataclasses.replace(cfg, num_experts=0, num_experts_per_tok=0,
                                   num_shared_experts=0, first_dense_layers=0,
                                   name=cfg.name + "-draft")
    else:
        dcfg = dataclasses.replace(cfg, num_layers=max(2, cfg.num_layers // 2),
                                   name=cfg.name + "-draft")
    return cfg, dcfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--policy", default="spmoe",
                    choices=("spmoe", "adapmoe", "moe-infinity", "on-demand",
                             "sd-only", "sd-adaptive", "greedy"))
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--cache-slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args()

    cfg, dcfg = reduced_pair(args.arch)
    target = build_model(cfg)
    draft = build_model(dcfg)
    tparams = target.init(jax.random.PRNGKey(0))
    # distilled draft stand-in: same init family, different seed
    dparams = draft.init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, args.prompt_len),
                                0, cfg.vocab_size)
    max_seq = args.prompt_len + args.tokens + args.draft_len + 8

    if args.policy == "greedy":
        out = greedy_generate(target, tparams, prompt, args.tokens, max_seq)
        print("tokens:", out.tolist())
        return
    if args.policy == "sd-only":
        out, stats = sd_generate(draft, target, dparams, tparams, prompt,
                                 args.tokens, args.draft_len, max_seq)
        print("tokens:", out.tolist())
        print("stats:", stats)
        return
    if args.policy == "sd-adaptive":
        from repro.core.sd import sd_generate_adaptive
        out, stats = sd_generate_adaptive(draft, target, dparams, tparams,
                                          prompt, args.tokens, max_seq)
        print("tokens:", out.tolist())
        print("stats:", stats)
        return
    assert cfg.is_moe, "offload policies need an MoE target"
    eng = OffloadEngine(cfg, dcfg, tparams, dparams,
                        cache_slots=args.cache_slots,
                        draft_len=args.draft_len, policy=args.policy,
                        max_seq=max_seq)
    out, stats = eng.generate(prompt, args.tokens)
    eng.close()
    print("tokens:", out.tolist())
    for k, v in stats.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
